"""Registry-driven gradient-parity suite for the training path.

Every backend declaring ``differentiable`` is gradchecked against the dense
``reference`` backend through the public ``nsa_attention(mode="train")``
entry, at GQA group sizes spanning the g<8 regime the vanilla-NSA loop order
cannot serve (g ∈ {1, 4, 16}).  This covers the fused Pallas backwards
(``fsa``, ``fsa_faithful``, ``flash_*`` save (out, lse) residuals and
recompute probabilities in the backward) and the XLA-twin fallbacks
(``nsa``, ``sparse_*``) through the same ``kernel_vjp`` machinery — a
backend registered tomorrow is gradchecked here with zero test changes.

All inputs are float32 and tolerances are tight: the fused backwards must be
numerically interchangeable with the XLA twin, not merely "close".
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.attention import NSAConfig, list_backends, nsa_attention
from repro.core import apply_gates, init_nsa_params

jax.config.update("jax_platform_name", "cpu")

CFG = NSAConfig(block_size=16, num_selected=4, cmp_block_size=8, cmp_stride=4,
                window_size=32, q_block_size=32, min_seq_for_sparse=1)
N, H_K, D, DM = 64, 2, 16, 32
GROUP_SIZES = (1, 4, 16)


def _state(g, seed=0):
    h = g * H_K
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    p = init_nsa_params(ks[0], DM, h, D, CFG)
    gates = apply_gates(p, jax.random.normal(ks[1], (N, DM)))
    q = jax.random.normal(ks[2], (N, h, D))
    k = jax.random.normal(ks[3], (N, H_K, D))
    v = jax.random.normal(ks[4], (N, H_K, D))
    return p, gates, q, k, v


def _qkv_grads(backend, algorithm, g, seed=0):
    p, gates, q, k, v = _state(g, seed)

    def loss(q, k, v):
        if algorithm == "nsa":
            out = nsa_attention(p, gates, q, k, v, cfg=CFG, mode="train",
                                backend=backend, needs_grad=True)
        else:
            out = nsa_attention(None, None, q, k, v, cfg=CFG, mode="train",
                                backend=backend, algorithm=algorithm,
                                needs_grad=True)
        return jnp.sum(out * out)

    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


def _differentiable(algorithm):
    return sorted(name for name, c in list_backends().items()
                  if c.differentiable and "train" in c.modes
                  and algorithm in c.algorithms and name != "reference")


def _assert_grads_match(name, algorithm, g):
    caps = list_backends()[name]
    if g < caps.min_g or (caps.max_g is not None and g > caps.max_g):
        pytest.skip(f"{name} declares g∈[{caps.min_g},{caps.max_g or '∞'}]")
    got = _qkv_grads(name, algorithm, g)
    want = _qkv_grads("reference", algorithm, g)
    for a, b, operand in zip(got, want, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-3,
            err_msg=f"d{operand} mismatch: {name}/{algorithm} g={g}")


@pytest.mark.parametrize("g", GROUP_SIZES)
@pytest.mark.parametrize("name", _differentiable("nsa"))
def test_grad_matches_reference_nsa(name, g):
    _assert_grads_match(name, "nsa", g)


@pytest.mark.parametrize("g", GROUP_SIZES)
@pytest.mark.parametrize("name", _differentiable("full"))
def test_grad_matches_reference_full(name, g):
    _assert_grads_match(name, "full", g)


@pytest.mark.parametrize("g", GROUP_SIZES)
@pytest.mark.parametrize("name", _differentiable("sliding"))
def test_grad_matches_reference_sliding(name, g):
    _assert_grads_match(name, "sliding", g)


def test_every_differentiable_backend_is_gradchecked():
    """No backend declaring differentiability escapes the sweeps above."""
    swept = (set(_differentiable("nsa")) | set(_differentiable("full"))
             | set(_differentiable("sliding")) | {"reference"})
    declared = {name for name, c in list_backends().items()
                if c.differentiable and "train" in c.modes}
    assert declared <= swept, f"ungradchecked backends: {declared - swept}"


def test_fused_backward_backends_declare_the_bit():
    """The backends this PR gave fused Pallas backwards advertise it, and
    nothing advertises a fused backward without being differentiable."""
    caps = list_backends()
    fused = {n for n, c in caps.items() if c.fused_backward}
    assert fused == {"fsa", "fsa_faithful", "flash_full", "flash_sliding"}
    assert all(caps[n].differentiable for n in fused)
