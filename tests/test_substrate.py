"""Optimizer / checkpoint / data / runtime / mamba / HLO-analysis tests."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import AdamWConfig, apply_updates, init_opt_state
from repro.optim.schedule import cosine_with_warmup
from repro.runtime.fault_tolerance import (Heartbeat, StragglerMonitor,
                                           elastic_mesh_for)


# ------------------------------------------------------------------ optim
def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params, cfg)
    target = jnp.array([1.0, 2.0])
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = apply_updates(params, grads, state, cfg)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_adamw_skips_nonfinite_grads():
    cfg = AdamWConfig(lr=0.1)
    params = {"w": jnp.ones(3)}
    state = init_opt_state(params, cfg)
    bad = {"w": jnp.array([jnp.nan, 1.0, 1.0])}
    new_params, new_state, m = apply_updates(params, bad, state, cfg)
    assert bool(m["skipped"])
    np.testing.assert_allclose(new_params["w"], params["w"])
    assert int(new_state["step"]) == 0


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params, cfg)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, m = apply_updates(params, huge, state, cfg)
    assert float(m["grad_norm"]) > 1e5  # raw norm reported pre-clip


def test_schedule_warmup_and_decay():
    s = jnp.arange(0, 1000)
    lr = cosine_with_warmup(s, warmup=100, total=1000)
    assert float(lr[0]) == 0.0
    assert float(lr[99]) <= 1.0 and float(lr[100]) == pytest.approx(1.0, abs=0.02)
    assert float(lr[-1]) < float(lr[200])


# ------------------------------------------------------------------ ckpt
def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "nested": {"b": jnp.ones(4, jnp.bfloat16)},
             "step": jnp.array(7)}
    ckpt.save(tmp_path, 10, state)
    restored, step = ckpt.restore_latest(tmp_path, state)
    assert step == 10
    np.testing.assert_allclose(restored["a"], state["a"])
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_retention_and_latest(tmp_path):
    state = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, state, keep=2)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [4, 5]
    assert ckpt.latest_step(tmp_path) == 5


def test_checkpoint_damaged_falls_back(tmp_path):
    state = {"a": jnp.zeros(2)}
    ckpt.save(tmp_path, 1, state)
    ckpt.save(tmp_path, 2, state)
    # damage newest: remove a leaf file
    victim = next((tmp_path / "step_2").glob("*.npy"))
    victim.unlink()
    assert ckpt.latest_step(tmp_path) == 1


# ------------------------------------------------------------------ data
def test_data_deterministic_and_resumable():
    data = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=2, seed=3))
    b1 = data.batch_at(17)
    b2 = data.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = data.batch_at(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert (np.asarray(b1["labels"][:, -1]) == -100).all()


# ------------------------------------------------------------------ runtime
def test_straggler_monitor():
    mon = StragglerMonitor(factor=2.0, window=20)
    flags = [mon.record(0.1) for _ in range(10)]
    assert not any(flags)
    assert mon.record(0.5) is True
    assert mon.flagged == 1


def test_heartbeat_staleness(tmp_path):
    hb = Heartbeat(tmp_path / "hb.json")
    hb.beat(5, loss=1.0)
    assert not hb.stale(timeout_s=60)
    rec = json.loads((tmp_path / "hb.json").read_text())
    assert rec["step"] == 5


def test_elastic_mesh_shapes():
    assert elastic_mesh_for(256) == ((16, 16), ("data", "model"))
    assert elastic_mesh_for(24) == ((3, 8), ("data", "model"))
    assert elastic_mesh_for(7) == ((7, 1), ("data", "model"))


# ------------------------------------------------------------------ mamba
def test_ssd_chunked_matches_naive_recurrence():
    from repro.models.mamba2 import ssd_chunked

    b, l, h, p, n = 2, 32, 3, 4, 5
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, l, h, n))
    cc = jax.random.normal(jax.random.fold_in(ks[3], 1), (b, l, h, n))

    # naive sequential recurrence
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        decay = jnp.exp(dt[:, t] * a)                      # (b,h)
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt[:, t], bb[:, t], x[:, t])
        ys.append(jnp.einsum("bhn,bhpn->bhp", cc[:, t], state))
    y_naive = jnp.stack(ys, axis=1)

    for chunk in (8, 16, 32):
        y, final = ssd_chunked(x, dt, a, bb, cc, chunk)
        np.testing.assert_allclose(y, y_naive, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(final, state, atol=1e-4, rtol=1e-4)


def test_mamba_decode_continues_forward():
    """Prefill state + one decode step == forward over S+1 tokens."""
    from repro.configs.base import ModelConfig, SSMConfig
    from repro.models.mamba2 import (init_mamba, mamba_decode_step,
                                     mamba_forward)

    cfg = ModelConfig(d_model=32, dtype="float32",
                      ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=8,
                                    chunk=8))
    p = init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 17, 32))
    y_full, _ = mamba_forward(p, x, cfg)
    y_pre, (conv, ssm) = mamba_forward(p, x[:, :16], cfg)
    y_t, _, _ = mamba_decode_step(p, x[:, 16], conv, ssm, cfg)
    np.testing.assert_allclose(y_t, y_full[:, 16], atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------ hlo analysis
def test_hlo_analyzer_trip_count_correction():
    from repro.launch.hlo_analysis import analyze

    def f_scan(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    def f_unroll(x, w):
        for i in range(4):
            x = jnp.tanh(x @ w[i])
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    rs = analyze(jax.jit(f_scan).lower(x, w).compile().as_text())
    ru = analyze(jax.jit(f_unroll).lower(x, w).compile().as_text())
    expected = 4 * 2 * 64 ** 3
    assert rs["flops"] == expected
    assert ru["flops"] == expected
