"""Paged-KV serving: pool invariants, paged-vs-dense equivalence,
decode-vs-prefill parity, mixed-length continuous batching."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import build
from repro.serving import Engine, PagePool, PagedNSACache, Request
from repro.serving.scheduler import Scheduler

jax.config.update("jax_platform_name", "cpu")

MAX_LEN = 96
CHUNK = 32


def _cfg(**over):
    return reduced(get_config("codeqwen1.5-7b"), **over)


def _dense_greedy(cfg, params, prompt, max_new, max_len=MAX_LEN):
    """Reference: dense-cache prefill + step-by-step decode for one prompt.
    Returns (tokens, per-step logits)."""
    model = build(cfg)
    cache = model.init_cache(1, max_len)
    batch = {"tokens": jnp.asarray(prompt)[None],
             "labels": jnp.full((1, len(prompt)), -100)}
    logits, cache = jax.jit(model.prefill)(params, cache, batch)
    all_logits = [np.asarray(logits[0, :cfg.vocab])]
    toks = [int(jnp.argmax(logits[0, :cfg.vocab]))]
    step = jax.jit(model.decode_step)
    for i in range(max_new - 1):
        pos = len(prompt) + i
        logits, cache = step(params, cache, jnp.asarray([toks[-1]]),
                             jnp.asarray([pos]))
        all_logits.append(np.asarray(logits[0, :cfg.vocab]))
        toks.append(int(jnp.argmax(logits[0, :cfg.vocab])))
    return toks, all_logits


# ---------------------------------------------------------------- pages
def test_page_pool_alloc_free_reset():
    pool = PagePool(num_pages=8, page_size=16)
    assert pool.available == 7          # page 0 reserved
    a = pool.alloc(3)
    b = pool.alloc(4)
    assert a is not None and b is not None and pool.available == 0
    assert pool.alloc(1) is None        # exhausted, no side effect
    pool.free(a)
    assert pool.available == 3 and pool.utilization() == pytest.approx(4 / 7)
    with pytest.raises(ValueError):
        pool.free([0])                  # dump page is not allocatable
    pool.reset()
    assert pool.available == 7


def test_cache_slot_lifecycle():
    cfg = _cfg()
    cache = PagedNSACache(cfg, n_slots=2, max_len=MAX_LEN)
    assert cache.alloc_slot(0, 80)
    raw_used = cache.pool.used
    assert raw_used == -(-80 // cache.page_size)
    table = cache.device_tables()["page_table"]
    assert int(table[0, 0]) != 0        # slot 0 mapped off the dump page
    assert int(table[1, 0]) == 0        # idle slot routes to the dump page
    cache.free_slot(0)
    assert cache.pool.used == 0 and cache.cmp_pool.used == 0


def test_scheduler_rejects_oversized_request():
    cfg = _cfg()
    cache = PagedNSACache(cfg, n_slots=1, max_len=MAX_LEN)
    sched = Scheduler(cache, prefill_chunk=CHUNK)
    with pytest.raises(ValueError):
        sched.submit(Request(prompt=np.arange(MAX_LEN), max_new=8))


# ------------------------------------------------------- paged vs dense
@pytest.mark.parametrize("attention", ["nsa", "full"])
def test_paged_matches_dense_logits(attention):
    """Same params, same token stream: paged storage must reproduce the
    dense cache's logits at prefill and at every decode step."""
    cfg = _cfg(attention=attention)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (37,), 0,
                                           cfg.vocab))
    max_new = 5
    dense_toks, dense_logits = _dense_greedy(cfg, params, prompt, max_new)

    eng = Engine(cfg, n_slots=2, max_len=MAX_LEN, prefill_chunk=CHUNK,
                 params=params)
    req = eng.submit(prompt, max_new=max_new)
    # drive manually so we can intercept per-step logits
    eng.scheduler.admit()
    eng._prefill_request(req)
    paged_logits = []
    toks = [req.out[0]]
    while len(toks) < max_new:
        pos = jnp.asarray(eng.cache.lengths, jnp.int32)
        logits, eng.cache.data = eng._decode(
            eng.params, eng.cache.data, jnp.asarray(eng._last_tokens), pos,
            eng.cache.device_tables())
        paged_logits.append(np.asarray(logits[req.slot, :cfg.vocab]))
        tok = int(jnp.argmax(logits[req.slot, :cfg.vocab]))
        toks.append(tok)
        eng._last_tokens[req.slot] = tok
        eng.cache.lengths[req.slot] += 1

    assert toks == dense_toks
    for d, p in zip(dense_logits[1:], paged_logits):
        np.testing.assert_allclose(d, p, rtol=2e-4, atol=2e-4)


def test_decode_matches_prefill_logits():
    """Decoding the prompt token-by-token reproduces the full-sequence
    (prefill-path) logits at every position."""
    cfg = _cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(6), (33,), 0,
                                           cfg.vocab))
    full = np.asarray(jax.jit(model.logits)(
        params, {"tokens": jnp.asarray(prompt)[None]})[0, :, :cfg.vocab])

    cache = model.init_cache(1, MAX_LEN)
    step = jax.jit(model.decode_step)
    for t in range(len(prompt)):
        logits, cache = step(params, cache, jnp.asarray([prompt[t]]),
                             jnp.asarray([t]))
        np.testing.assert_allclose(full[t], np.asarray(logits[0, :cfg.vocab]),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"position {t}")


def test_decode_scalar_pos_backcompat():
    """decode_step accepts scalar pos (broadcast) and a (B,) vector."""
    cfg = _cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(2))
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (2, 16), 0,
                                           cfg.vocab))
    batch = {"tokens": jnp.asarray(prompt),
             "labels": jnp.full_like(jnp.asarray(prompt), -100)}
    cache = model.init_cache(2, 48)
    _, cache = jax.jit(model.prefill)(params, cache, batch)
    toks = jnp.asarray([3, 4])
    l_scalar, _ = jax.jit(model.decode_step)(params, cache, toks,
                                             jnp.asarray(16))
    l_vec, _ = jax.jit(model.decode_step)(params, cache, toks,
                                          jnp.asarray([16, 16]))
    np.testing.assert_allclose(np.asarray(l_scalar), np.asarray(l_vec),
                               rtol=1e-6, atol=1e-6)


# -------------------------------------------------- continuous batching
def test_engine_mixed_length_continuous_batching():
    """More variable-length requests than slots: admission over time, slot
    recycling, page reclamation — and every request still decodes exactly
    its dense-reference greedy tokens."""
    cfg = _cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(3))
    lengths = [19, 40, 9, 27]
    max_new = 4
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(10 + i),
                                             (n,), 0, cfg.vocab))
               for i, n in enumerate(lengths)]

    eng = Engine(cfg, n_slots=2, max_len=MAX_LEN, prefill_chunk=CHUNK,
                 params=params)
    reqs = [eng.submit(p, max_new=max_new) for p in prompts]
    assert eng.scheduler.pending == 4
    summary = eng.run()

    assert summary["requests_finished"] == 4
    assert eng.cache.pool.used == 0 and eng.cache.cmp_pool.used == 0
    assert summary["peak_page_util"] > 0
    for req, prompt in zip(reqs, prompts):
        ref_toks, _ = _dense_greedy(cfg, params, prompt, max_new)
        assert list(req.out) == ref_toks, f"rid {req.rid} diverged"


def test_engine_eos_recycles_slot():
    cfg = _cfg()
    eng = Engine(cfg, n_slots=1, max_len=MAX_LEN, prefill_chunk=CHUNK)
    prompt = np.arange(1, 12) % cfg.vocab
    # whatever greedy emits first becomes the EOS id -> finish after 1 token
    probe = eng.submit(prompt, max_new=1)
    eng.run()
    eos = probe.out[0]
    eng2 = Engine(cfg, n_slots=1, max_len=MAX_LEN, prefill_chunk=CHUNK,
                  params=eng.params)
    req = eng2.submit(prompt, max_new=8, eos_id=eos)
    eng2.run()
    assert req.out[-1] == eos and len(req.out) == 1
    assert eng2.cache.pool.used == 0
