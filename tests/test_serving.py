"""Paged-KV serving: pool invariants, paged-vs-dense equivalence,
decode-vs-prefill parity, mixed-length continuous batching, and the Pallas
paged-decode kernel (kernel-vs-gather-reference equivalence, page-table
permutation invariance, batched-vs-sequential parity)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.nsa_config import NSAConfig
from repro.kernels import ops
from repro.models import build
from repro.serving import Engine, PagePool, PagedNSACache, Request
from repro.serving.scheduler import Scheduler

jax.config.update("jax_platform_name", "cpu")

MAX_LEN = 96
CHUNK = 32


def _cfg(**over):
    return reduced(get_config("codeqwen1.5-7b"), **over)


def _dense_greedy(cfg, params, prompt, max_new, max_len=MAX_LEN):
    """Reference: dense-cache prefill + step-by-step decode for one prompt.
    Returns (tokens, per-step logits)."""
    model = build(cfg)
    cache = model.init_cache(1, max_len)
    batch = {"tokens": jnp.asarray(prompt)[None],
             "labels": jnp.full((1, len(prompt)), -100)}
    logits, cache = jax.jit(model.prefill)(params, cache, batch)
    all_logits = [np.asarray(logits[0, :cfg.vocab])]
    toks = [int(jnp.argmax(logits[0, :cfg.vocab]))]
    step = jax.jit(model.decode_step)
    for i in range(max_new - 1):
        pos = len(prompt) + i
        logits, cache = step(params, cache, jnp.asarray([toks[-1]]),
                             jnp.asarray([pos]))
        all_logits.append(np.asarray(logits[0, :cfg.vocab]))
        toks.append(int(jnp.argmax(logits[0, :cfg.vocab])))
    return toks, all_logits


# ---------------------------------------------------------------- pages
def test_page_pool_lease_release_reset():
    pool = PagePool(num_pages=8, page_size=16)
    assert pool.available == 7          # page 0 reserved
    a = pool.try_alloc(3)
    b = pool.try_alloc(4)
    assert a is not None and b is not None and pool.available == 0
    assert pool.try_alloc(1) is None    # exhausted, no side effect
    a.release()
    assert pool.available == 3 and pool.utilization() == pytest.approx(4 / 7)
    a.release()                         # idempotent: refs dropped only once
    assert pool.available == 3
    taken = b.take()                    # ownership leaves the lease
    b.release()                         # ...so this is a no-op
    assert pool.available == 3
    with pytest.raises(ValueError):
        pool.release([0])               # dump page is not allocatable
    pool.release(taken)
    assert pool.available == 7
    pool.reset()
    assert pool.available == 7


def test_page_pool_deprecated_alloc_free_shims():
    """The pre-lease spellings still work (one-release shims) and warn."""
    pool = PagePool(num_pages=8, page_size=16)
    with pytest.warns(DeprecationWarning, match="try_alloc"):
        a = pool.alloc(3)
    assert a is not None and pool.available == 4
    with pytest.warns(DeprecationWarning, match="release"):
        pool.free(a)
    assert pool.available == 7
    with pytest.warns(DeprecationWarning):
        assert pool.alloc(8) is None    # exhaustion contract unchanged


def test_cache_slot_lifecycle():
    cfg = _cfg()
    cache = PagedNSACache(cfg, n_slots=2, max_len=MAX_LEN)
    assert cache.alloc_slot(0, 80)
    raw_used = cache.pool.used
    assert raw_used == -(-80 // cache.page_size)
    table = cache.views()["page_table"]
    assert int(table[0, 0]) != 0        # slot 0 mapped off the dump page
    assert int(table[1, 0]) == 0        # idle slot routes to the dump page
    cache.free_slot(0)
    assert cache.pool.used == 0 and cache.cmp_pool.used == 0


def test_cache_deprecated_view_accessors():
    """The five pre-``views()`` accessors warn and return the same payload."""
    cfg = _cfg()
    cache = PagedNSACache(cfg, n_slots=2, max_len=MAX_LEN)
    assert cache.alloc_slot(0, 80) and cache.alloc_slot(1, 48)
    with pytest.warns(DeprecationWarning, match="views"):
        old = cache.device_tables()
    new = cache.views()
    np.testing.assert_array_equal(np.asarray(old["page_table"]),
                                  np.asarray(new["page_table"]))
    with pytest.warns(DeprecationWarning, match="views"):
        old1 = cache.slot_tables(1)
    np.testing.assert_array_equal(np.asarray(old1["page_table"]),
                                  np.asarray(new["page_table"][1]))
    with pytest.warns(DeprecationWarning, match="views"):
        oldb = cache.slot_tables_batch([1], batch_size=2)
    np.testing.assert_array_equal(np.asarray(oldb["page_table"][0]),
                                  np.asarray(new["page_table"][1]))
    assert not np.asarray(oldb["page_table"][1]).any()   # pad row -> dump
    with pytest.warns(DeprecationWarning, match="views"):
        gv = cache.gather_view(0, layer=0)
    assert set(gv) == {"k", "v", "cmp_k", "cmp_v"}       # dense payload only
    np.testing.assert_array_equal(
        np.asarray(gv["k"]), np.asarray(cache.views(0, layer=0)["k"]))
    with pytest.warns(DeprecationWarning, match="views"):
        gvs = cache.gather_views([0, 1], layer=0)
    np.testing.assert_array_equal(np.asarray(gvs["k"][0]),
                                  np.asarray(gv["k"]))


def test_scheduler_admit_limit():
    """admit(limit) caps the admission batch even with free slots/pages."""
    cfg = _cfg()
    cache = PagedNSACache(cfg, n_slots=3, max_len=MAX_LEN)
    sched = Scheduler(cache, prefill_chunk=CHUNK)
    for n in (8, 9, 10):
        sched.submit(Request(prompt=np.arange(1, n), max_new=4))
    assert len(sched.admit(limit=2)) == 2
    assert sched.pending == 1
    assert len(sched.admit()) == 1          # no limit: fill remaining slot


def test_scheduler_token_budget_admission():
    """admit(token_budget=...) stops admitting once in-flight + next chunk
    tokens would exceed the budget — but never wedges an empty engine."""
    cfg = _cfg()
    cache = PagedNSACache(cfg, n_slots=4, max_len=MAX_LEN)
    sched = Scheduler(cache, prefill_chunk=CHUNK)
    for _ in range(4):
        sched.submit(Request(prompt=np.arange(1, 41), max_new=4))  # 40 toks
    # chunk_tokens = min(CHUNK, 40) = 32 each; budget 64 -> two admitted
    got = sched.admit(token_budget=2 * CHUNK)
    assert len(got) == 2 and sched.pending == 2
    # in-flight already at budget: nothing more comes in
    assert sched.admit(token_budget=2 * CHUNK,
                       tokens_in_flight=2 * CHUNK) == []
    # a budget below one chunk still admits when nothing is in flight
    for r in got:
        sched.release(r)
    assert len(sched.admit(token_budget=CHUNK // 2)) == 1


def test_scheduler_rejects_oversized_request():
    cfg = _cfg()
    cache = PagedNSACache(cfg, n_slots=1, max_len=MAX_LEN)
    sched = Scheduler(cache, prefill_chunk=CHUNK)
    with pytest.raises(ValueError):
        sched.submit(Request(prompt=np.arange(MAX_LEN), max_new=8))


# ------------------------------------------------------- paged vs dense
@pytest.mark.parametrize("attention", ["nsa", "full"])
def test_paged_matches_dense_logits(attention):
    """Same params, same token stream: paged storage must reproduce the
    dense cache's logits at prefill and at every decode step."""
    cfg = _cfg(attention=attention)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (37,), 0,
                                           cfg.vocab))
    max_new = 5
    dense_toks, dense_logits = _dense_greedy(cfg, params, prompt, max_new)

    eng = Engine(cfg, n_slots=2, max_len=MAX_LEN, prefill_chunk=CHUNK,
                 params=params)
    req = eng.submit(prompt, max_new=max_new)
    # drive manually so we can intercept per-step logits
    eng.scheduler.admit()
    eng._prefill_request(req)
    paged_logits = []
    toks = [req.out[0]]
    while len(toks) < max_new:
        pos = jnp.asarray(eng.cache.lengths, jnp.int32)
        logits, eng.cache.data = eng._decode(
            eng.params, eng.cache.data, jnp.asarray(eng._last_tokens), pos,
            eng.cache.views())
        paged_logits.append(np.asarray(logits[req.slot, :cfg.vocab]))
        tok = int(jnp.argmax(logits[req.slot, :cfg.vocab]))
        toks.append(tok)
        eng._last_tokens[req.slot] = tok
        eng.cache.lengths[req.slot] += 1

    assert toks == dense_toks
    for d, p in zip(dense_logits[1:], paged_logits):
        np.testing.assert_allclose(d, p, rtol=2e-4, atol=2e-4)


def test_decode_matches_prefill_logits():
    """Decoding the prompt token-by-token reproduces the full-sequence
    (prefill-path) logits at every position."""
    cfg = _cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(6), (33,), 0,
                                           cfg.vocab))
    full = np.asarray(jax.jit(model.logits)(
        params, {"tokens": jnp.asarray(prompt)[None]})[0, :, :cfg.vocab])

    cache = model.init_cache(1, MAX_LEN)
    step = jax.jit(model.decode_step)
    for t in range(len(prompt)):
        logits, cache = step(params, cache, jnp.asarray([prompt[t]]),
                             jnp.asarray([t]))
        np.testing.assert_allclose(full[t], np.asarray(logits[0, :cfg.vocab]),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"position {t}")


def test_decode_scalar_pos_backcompat():
    """decode_step accepts scalar pos (broadcast) and a (B,) vector."""
    cfg = _cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(2))
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (2, 16), 0,
                                           cfg.vocab))
    batch = {"tokens": jnp.asarray(prompt),
             "labels": jnp.full_like(jnp.asarray(prompt), -100)}
    cache = model.init_cache(2, 48)
    _, cache = jax.jit(model.prefill)(params, cache, batch)
    toks = jnp.asarray([3, 4])
    l_scalar, _ = jax.jit(model.decode_step)(params, cache, toks,
                                             jnp.asarray(16))
    l_vec, _ = jax.jit(model.decode_step)(params, cache, toks,
                                          jnp.asarray([16, 16]))
    np.testing.assert_allclose(np.asarray(l_scalar), np.asarray(l_vec),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------------- paged decode kernel
def _rand_paged_state(seed=0, slots=3, h_k=2, g=2, d=16, max_pages=6,
                      n_pages=32):
    """Random paged decode operands with per-slot page tables mapping onto a
    shuffled set of physical (non-dump) pages."""
    cfg = NSAConfig(block_size=16, num_selected=4, cmp_block_size=8,
                    cmp_stride=4, window_size=32, q_block_size=16)
    p = cfg.block_size
    h = h_k * g
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    state = {
        "cfg": cfg,
        "q": jax.random.normal(ks[0], (slots, h, d)),
        "gates": jax.nn.softmax(jax.random.normal(ks[1], (slots, h, 3)), -1),
        "k_pages": jax.random.normal(ks[2], (n_pages, p, h_k, d)),
        "v_pages": jax.random.normal(ks[3], (n_pages, p, h_k, d)),
    }
    perm = np.random.default_rng(seed).permutation(np.arange(1, n_pages))
    state["tables"] = jnp.asarray(
        perm[:slots * max_pages].reshape(slots, max_pages), jnp.int32)
    n_cmp = cfg.num_cmp_blocks(max_pages * p)
    state["cmp_k"] = jax.random.normal(ks[4], (slots, n_cmp, h_k, d))
    state["cmp_v"] = jax.random.normal(ks[5], (slots, n_cmp, h_k, d))
    state["pos"] = jnp.asarray(
        np.random.default_rng(seed + 1).integers(0, max_pages * p,
                                                 size=(slots,)), jnp.int32)
    return state


def _run_paged(st, *, backend, tables=None, k_pages=None, v_pages=None,
               block_s=None):
    return ops.paged_decode_attention_batched(
        st["gates"], st["q"],
        st["k_pages"] if k_pages is None else k_pages,
        st["v_pages"] if v_pages is None else v_pages,
        st["tables"] if tables is None else tables,
        st["cmp_k"], st["cmp_v"], st["pos"], st["cfg"],
        backend=backend, block_s=block_s)


def test_paged_kernel_matches_gather_reference():
    """Interpret-mode Pallas paged-decode == gather-through-page-table
    reference, at fp32 tolerance, across uneven slot positions."""
    st = _rand_paged_state()
    ref = _run_paged(st, backend="paged_gather")
    ker = _run_paged(st, backend="paged_kernel")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               rtol=1e-5, atol=1e-5)


def test_page_table_permutation_invariance():
    """Physically shuffling pages (and remapping the tables accordingly)
    must not change a single logit: the kernel addresses KV only through
    the page table."""
    st = _rand_paged_state(seed=3)
    n_pages = st["k_pages"].shape[0]
    base = _run_paged(st, backend="paged_kernel")

    rng = np.random.default_rng(7)
    perm = np.concatenate([[0], 1 + rng.permutation(n_pages - 1)])  # keep dump
    perm_j = jnp.asarray(perm)
    # physical page p moves to slot perm[p]; tables follow
    k_shuf = jnp.zeros_like(st["k_pages"]).at[perm_j].set(st["k_pages"])
    v_shuf = jnp.zeros_like(st["v_pages"]).at[perm_j].set(st["v_pages"])
    tables_shuf = perm_j[st["tables"]].astype(jnp.int32)
    shuf = _run_paged(st, backend="paged_kernel", tables=tables_shuf,
                      k_pages=k_shuf, v_pages=v_shuf)
    np.testing.assert_allclose(np.asarray(base), np.asarray(shuf),
                               rtol=1e-6, atol=1e-6)


def test_batched_vs_sequential_decode_parity():
    """One batched multi-slot kernel call == per-slot single-slot calls of
    the public API (both on the kernel path), including when the slot count
    does not divide the fold block (slot-padding path)."""
    st = _rand_paged_state(seed=5)                    # 3 slots
    batched = _run_paged(st, backend="paged_kernel")
    padded = _run_paged(st, backend="paged_kernel", block_s=2)  # 3 % 2 != 0
    np.testing.assert_allclose(np.asarray(batched), np.asarray(padded),
                               rtol=1e-5, atol=1e-5)
    for b in range(st["q"].shape[0]):
        single = ops.paged_decode_attention(
            st["gates"][b], st["q"][b], st["k_pages"], st["v_pages"],
            st["tables"][b], st["cmp_k"][b], st["cmp_v"][b], st["pos"][b],
            st["cfg"], backend="paged_kernel")
        np.testing.assert_allclose(np.asarray(batched[b]), np.asarray(single),
                                   rtol=1e-5, atol=1e-5, err_msg=f"slot {b}")


def test_engine_decode_is_one_batched_dispatch(monkeypatch):
    """Every engine tick must trace batched paged-decode dispatches only
    (the lax.scan over layers traces its body once per compiled program —
    the fused mixed tick and the steady-state decode tick), never one
    dispatch per slot."""
    from repro.attention import backends as attn_backends

    calls = []
    real = attn_backends.paged_decode_attention

    def counting(*args, **kwargs):
        calls.append(args[1].shape)          # q: (B, h, d)
        return real(*args, **kwargs)

    monkeypatch.setattr(attn_backends, "paged_decode_attention", counting)
    cfg = _cfg()
    eng = Engine(cfg, n_slots=2, max_len=MAX_LEN, prefill_chunk=CHUNK)
    eng.submit(np.arange(1, 10) % cfg.vocab, max_new=2)
    eng.submit(np.arange(2, 13) % cfg.vocab, max_new=2)
    eng.run()
    assert 1 <= len(calls) <= 2, \
        f"expected <=2 traced programs (mixed + decode), saw {len(calls)}"
    assert all(shape[0] == 2 for shape in calls)   # full slot batch at once


# ------------------------------------------------------ fused mixed tick
def _mixed_traffic(cfg, lengths):
    return [np.asarray(jax.random.randint(jax.random.PRNGKey(10 + i),
                                          (n,), 0, cfg.vocab))
            for i, n in enumerate(lengths)]


def test_fused_tick_matches_sequential_engine():
    """The fused mixed tick (chunked prefill co-scheduled with decode in one
    dispatch) must emit token-identical outputs to the sequential
    prefill-then-decode engine on mixed-length traffic."""
    cfg = _cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(3))
    prompts = _mixed_traffic(cfg, [19, 40, 9, 27])

    outs = {}
    for fused in (False, True):
        eng = Engine(cfg, n_slots=2, max_len=MAX_LEN, prefill_chunk=CHUNK,
                     params=params, fused=fused)
        reqs = [eng.submit(p, max_new=4) for p in prompts]
        summary = eng.run()
        assert summary["requests_finished"] == 4
        assert eng.cache.pool.used == 0 and eng.cache.cmp_pool.used == 0
        outs[fused] = [list(r.out) for r in reqs]
    assert outs[True] == outs[False]


def test_fused_tick_overlaps_prefill_with_decode():
    """While a long prompt prefills chunk by chunk, already-active slots
    keep decoding: the run must contain mixed ticks, and the decoding
    request must gain tokens DURING the long prompt's prefill."""
    cfg = _cfg()
    eng = Engine(cfg, n_slots=2, max_len=MAX_LEN, prefill_chunk=CHUNK)
    assert eng.prefill_chunk == CHUNK
    short = eng.submit(np.arange(1, 8) % cfg.vocab, max_new=12)
    eng.step()                                   # short prefills, 1st token
    assert len(short.out) == 1
    long = eng.submit(np.arange(1, 80) % cfg.vocab, max_new=2)   # 3 chunks
    seen = []
    while long.first_token_t is None:
        eng.step()
        seen.append(len(short.out))
    # short gained a token on every tick the long prompt spent prefilling
    assert seen == sorted(seen) and seen[0] >= 2 and len(seen) >= 3
    assert eng.stats["mixed_ticks"] >= 3
    eng.run()


def test_prefill_token_budget_bounds_per_tick_chunk_tokens():
    """With prefill_token_budget=B, no fused tick processes more than B
    prefill chunk tokens (admission throttles co-scheduled prefills), yet
    all traffic still drains."""
    cfg = _cfg()
    budget = CHUNK          # one chunk per tick across ALL prefilling slots
    eng = Engine(cfg, n_slots=4, max_len=MAX_LEN, prefill_chunk=CHUNK,
                 prefill_token_budget=budget)
    reqs = [eng.submit(np.arange(1, 40 + 7 * i) % cfg.vocab, max_new=3)
            for i in range(4)]
    ticks = []
    while not eng.scheduler.idle():
        ticks.append(eng.step()["prefill_chunk_tokens"])
    assert max(ticks) <= budget, f"tick exceeded budget: {ticks}"
    assert all(len(r.out) == 3 for r in reqs)
    # sanity: without the budget the same traffic co-prefills more per tick
    eng2 = Engine(cfg, n_slots=4, max_len=MAX_LEN, prefill_chunk=CHUNK)
    for i in range(4):
        eng2.submit(np.arange(1, 40 + 7 * i) % cfg.vocab, max_new=3)
    peak = 0
    while not eng2.scheduler.idle():
        peak = max(peak, eng2.step()["prefill_chunk_tokens"])
    assert peak > budget


def test_first_token_timestamp_per_request_after_sync():
    """first_token_t is stamped per request AFTER its first token is on
    host: distinct stamps per co-admitted request, ordered with emission,
    never before submit."""
    cfg = _cfg()
    eng = Engine(cfg, n_slots=2, max_len=MAX_LEN, prefill_chunk=CHUNK,
                 fused=False)                   # sequential admission batch
    r1 = eng.submit(np.arange(1, 20) % cfg.vocab, max_new=2)
    r2 = eng.submit(np.arange(2, 30) % cfg.vocab, max_new=2)
    eng.run()
    assert r1.first_token_t is not None and r2.first_token_t is not None
    assert r1.first_token_t != r2.first_token_t      # not one shared stamp
    assert r1.first_token_t < r2.first_token_t       # emission order
    for r in (r1, r2):
        assert r.submit_t < r.first_token_t <= r.finish_t


def test_released_slot_rides_inert_and_recycles_cleanly():
    """Regression: a freed slot's ride-along decode must write only to the
    dump page (never a free physical page), its stale last-token state is
    zeroed on release, and a later occupant of the same slot decodes
    exactly its dense-reference tokens."""
    cfg = _cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(4))
    eng = Engine(cfg, n_slots=2, max_len=MAX_LEN, prefill_chunk=CHUNK,
                 params=params)
    keep = eng.submit(np.arange(1, 30) % cfg.vocab, max_new=10)
    brief = eng.submit(np.arange(3, 12) % cfg.vocab, max_new=1)
    while brief.state != "done":
        eng.step()
    slot = brief.slot
    assert eng._last_tokens[slot] == 0               # stale token zeroed
    # pages not owned by the surviving request must stay untouched while
    # the freed slot rides along in subsequent decode ticks
    owned = set(np.asarray(eng.cache.tables[keep.slot].as_row()).tolist())
    free_pages = [i for i in range(1, eng.cache.num_pages) if i not in owned]
    before = np.asarray(
        jax.tree.map(lambda a: a[0], eng.cache.data["layers"])["k_pages"]
    )[free_pages].copy()
    for _ in range(3):
        eng.step()
    after = np.asarray(
        jax.tree.map(lambda a: a[0], eng.cache.data["layers"])["k_pages"]
    )[free_pages]
    np.testing.assert_array_equal(before, after)
    # a new occupant of the recycled slot is bit-exact vs dense reference
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(21), (13,), 0,
                                           cfg.vocab))
    nxt = eng.submit(prompt, max_new=3)
    eng.run()
    assert nxt.slot == slot
    ref_toks, _ = _dense_greedy(cfg, params, prompt, 3)
    assert list(nxt.out) == ref_toks


# -------------------------------------------------- continuous batching
def test_engine_mixed_length_continuous_batching():
    """More variable-length requests than slots: admission over time, slot
    recycling, page reclamation — and every request still decodes exactly
    its dense-reference greedy tokens."""
    cfg = _cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(3))
    lengths = [19, 40, 9, 27]
    max_new = 4
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(10 + i),
                                             (n,), 0, cfg.vocab))
               for i, n in enumerate(lengths)]

    eng = Engine(cfg, n_slots=2, max_len=MAX_LEN, prefill_chunk=CHUNK,
                 params=params)
    reqs = [eng.submit(p, max_new=max_new) for p in prompts]
    assert eng.scheduler.pending == 4
    summary = eng.run()

    assert summary["requests_finished"] == 4
    assert eng.cache.pool.used == 0 and eng.cache.cmp_pool.used == 0
    assert summary["peak_page_util"] > 0
    for req, prompt in zip(reqs, prompts):
        ref_toks, _ = _dense_greedy(cfg, params, prompt, max_new)
        assert list(req.out) == ref_toks, f"rid {req.rid} diverged"


def test_engine_eos_recycles_slot():
    cfg = _cfg()
    eng = Engine(cfg, n_slots=1, max_len=MAX_LEN, prefill_chunk=CHUNK)
    prompt = np.arange(1, 12) % cfg.vocab
    # whatever greedy emits first becomes the EOS id -> finish after 1 token
    probe = eng.submit(prompt, max_new=1)
    eng.run()
    eos = probe.out[0]
    eng2 = Engine(cfg, n_slots=1, max_len=MAX_LEN, prefill_chunk=CHUNK,
                  params=eng.params)
    req = eng2.submit(prompt, max_new=8, eos_id=eos)
    eng2.run()
    assert req.out[-1] == eos and len(req.out) == 1
    assert eng2.cache.pool.used == 0
