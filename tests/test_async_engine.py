"""Async serving loop: per-request token streaming over the fused engine —
concurrent streams match the synchronous engine token-for-token, the service
loop survives bursts (drain + restart), and the corrected per-request
latency timestamps come out ordered."""
import asyncio

import numpy as np
import pytest

import jax

from repro.configs import get_config, reduced
from repro.models import build
from repro.serving import AsyncEngine, Engine

jax.config.update("jax_platform_name", "cpu")

MAX_LEN = 96
CHUNK = 32


def _cfg(**over):
    return reduced(get_config("codeqwen1.5-7b"), **over)


def _prompts(cfg, lengths):
    return [np.asarray(jax.random.randint(jax.random.PRNGKey(30 + i), (n,),
                                          0, cfg.vocab))
            for i, n in enumerate(lengths)]


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = build(cfg).init(jax.random.PRNGKey(5))
    return cfg, params


def _sync_outputs(cfg, params, prompts, max_new):
    eng = Engine(cfg, n_slots=2, max_len=MAX_LEN, prefill_chunk=CHUNK,
                 params=params)
    reqs = [eng.submit(p, max_new=max_new) for p in prompts]
    eng.run()
    return [list(r.out) for r in reqs]


def test_concurrent_streams_match_sync_engine(setup):
    """N coroutines streaming concurrently receive exactly the tokens the
    synchronous fused engine emits for the same traffic."""
    cfg, params = setup
    prompts = _prompts(cfg, [19, 40, 9])
    want = _sync_outputs(cfg, params, prompts, max_new=4)

    aeng = AsyncEngine(Engine(cfg, n_slots=2, max_len=MAX_LEN,
                              prefill_chunk=CHUNK, params=params))

    async def main():
        async def collect(p):
            return [t async for t in aeng.stream(p, max_new=4)]
        return await asyncio.gather(*[collect(p) for p in prompts])

    got = asyncio.run(main())
    assert got == want


def test_generate_restarts_loop_and_stamps_latency(setup):
    """generate() after the service loop drained restarts it; the finished
    request carries ordered per-request timestamps (submit < first token
    <= finish) and respects eos."""
    cfg, params = setup
    prompt = _prompts(cfg, [21])[0]
    aeng = AsyncEngine(Engine(cfg, n_slots=2, max_len=MAX_LEN,
                              prefill_chunk=CHUNK, params=params))

    async def main():
        first = await aeng.generate(prompt, max_new=3)
        await aeng.drain()                       # loop idles...
        second = await aeng.generate(prompt, max_new=3)   # ...and restarts
        return first, second

    first, second = asyncio.run(main())
    assert list(first.out) == list(second.out) and len(first.out) == 3
    for r in (first, second):
        assert r.submit_t < r.first_token_t <= r.finish_t


def test_stream_respects_eos(setup):
    """A streamed request stops at eos_id; the stream closes after it."""
    cfg, params = setup
    prompt = _prompts(cfg, [15])[0]
    probe = _sync_outputs(cfg, params, [prompt], max_new=1)[0]

    aeng = AsyncEngine(Engine(cfg, n_slots=1, max_len=MAX_LEN,
                              prefill_chunk=CHUNK, params=params))

    async def main():
        return [t async for t in aeng.stream(prompt, max_new=8,
                                             eos_id=probe[0])]

    toks = asyncio.run(main())
    assert toks == probe                          # stopped at the first token
