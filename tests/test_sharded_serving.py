"""Mesh-sharded paged serving (8 forced host devices, run in a subprocess
so the main pytest process keeps its single-device view).

Covers: exact token parity of ``ShardedEngine`` on 2x4 and 4x2
(data, model) meshes against the single-device ``Engine`` on mixed-length
continuous-batching traffic — with and without the prefix cache — the
1x1-mesh fallback to the plain engine, and the structured
``MeshLayoutError`` cases (model axis vs n_kv_heads, data axis vs slots).
"""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import warnings; warnings.filterwarnings("ignore")
    import jax
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_mesh
    from repro.serving import Engine, MeshLayoutError, ShardedEngine

    # reduced defaults are 4 q-heads / 2 kv-heads — too small for a model
    # axis of 4, so widen the head axes (algorithm unchanged)
    cfg = reduced(get_config("h2o-danube-3-4b"), n_heads=8, n_kv_heads=4)

    def run(prompts, mesh=None, prefix=False, n_slots=4):
        eng = Engine(cfg, n_slots=n_slots, max_len=96, mesh=mesh,
                     prefix_cache=prefix)
        reqs = [eng.submit(p, max_new=4) for p in prompts]
        summary = eng.run()
        return eng, [list(r.out) for r in reqs], summary

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=(n,)).astype(np.int32)
               for n in (9, 21, 14, 33, 17, 8)]     # 6 reqs > 4 slots

    ref_eng, ref_out, _ = run(prompts)
    assert type(ref_eng) is Engine

    # ---- exact token parity on both mesh factorizations ----
    for shape in ((2, 4), (4, 2)):
        mesh = make_mesh(shape, ("data", "model"))
        eng, out, _ = run(prompts, mesh=mesh)
        assert isinstance(eng, ShardedEngine), type(eng)
        assert eng.n_data * eng.n_model == 8
        assert out == ref_out, (shape, out, ref_out)
        print("parity %dx%d OK" % shape)

    # ---- prefix-cache parity: 6 of 8 prompts share a 48-token prefix ----
    shared = rng.integers(0, cfg.vocab, size=(48,)).astype(np.int32)
    pp = []
    for i in range(8):
        if i % 4 != 3:
            tail = rng.integers(0, cfg.vocab, size=(
                int(rng.integers(1, 16)),)).astype(np.int32)
            pp.append(np.concatenate([shared, tail]))
        else:
            pp.append(rng.integers(0, cfg.vocab, size=(
                int(rng.integers(8, 40)),)).astype(np.int32))
    _, ref_pp, _ = run(pp)                 # reference: prefix cache OFF
    for shape in ((2, 4), (4, 2)):
        mesh = make_mesh(shape, ("data", "model"))
        _, out, s = run(pp, mesh=mesh, prefix=True)
        assert out == ref_pp, (shape, out, ref_pp)
        assert s["prefix_blocks_reused"] > 0, s
        print("prefix parity %dx%d OK reused" % shape,
              s["prefix_blocks_reused"])

    # ---- 1x1 mesh routes to the plain engine, same tokens ----
    eng11, out11, _ = run(prompts, mesh=make_mesh((1, 1), ("data", "model")))
    assert type(eng11) is Engine, type(eng11)
    assert out11 == ref_out
    print("mesh 1x1 OK")

    # ---- structured layout errors ----
    try:
        ShardedEngine(cfg, n_slots=4, max_len=96,
                      mesh=make_mesh((1, 8), ("data", "model")))
        raise SystemExit("expected MeshLayoutError (model axis)")
    except MeshLayoutError as e:
        assert "n_kv_heads" in str(e), e
        assert (4, 2) in e.valid and (2, 4) in e.valid, e.valid
    try:
        ShardedEngine(cfg, n_slots=5, max_len=96,
                      mesh=make_mesh((2, 4), ("data", "model")))
        raise SystemExit("expected MeshLayoutError (data axis)")
    except MeshLayoutError as e:
        assert "n_slots" in str(e), e
    print("layout errors OK")
""")


@pytest.mark.slow
def test_sharded_serving_suite():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"}, cwd="/root/repo", timeout=1200)
    assert "parity 2x4 OK" in r.stdout, r.stdout + r.stderr
    assert "parity 4x2 OK" in r.stdout, r.stdout + r.stderr
    assert "prefix parity 2x4 OK" in r.stdout, r.stdout + r.stderr
    assert "prefix parity 4x2 OK" in r.stdout, r.stdout + r.stderr
    assert "mesh 1x1 OK" in r.stdout, r.stdout + r.stderr
    assert "layout errors OK" in r.stdout, r.stdout + r.stderr
