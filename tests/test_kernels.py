"""Kernel sweeps: every Pallas kernel vs its pure-jnp oracle (interpret mode).

Sweeps shapes (incl. ragged N), dtypes, GQA group sizes, block sizes, dk!=dv.
Kernels are addressed by registry name through
``repro.attention.selected_attention(..., kernel=...)``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.attention import selected_attention
from repro.core import NSAConfig
from repro.core.selection import select_blocks
from repro.kernels import ops, ref


def make_inputs(key, n, h, h_k, dk, dv, t_sel, b_k, dtype):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (n, h, dk), dtype)
    k = jax.random.normal(ks[1], (n, h_k, dk), dtype)
    v = jax.random.normal(ks[2], (n, h_k, dv), dtype)
    # random causal selection (always includes the current block)
    b = (n + b_k - 1) // b_k
    scores = jax.random.uniform(ks[3], (n, h_k, b))
    cfg = NSAConfig(block_size=b_k, num_selected=t_sel, cmp_block_size=8,
                    cmp_stride=4, window_size=32, q_block_size=32,
                    num_init_blocks=1, num_local_blocks=1,
                    min_seq_for_sparse=1)
    idx, valid = select_blocks(scores, jnp.arange(n), cfg, n)
    return q, k, v, idx, valid, cfg


KERNELS = ["fsa", "fsa_faithful", "nsa"]


@pytest.mark.parametrize("kernel", KERNELS)
@pytest.mark.parametrize("n,g,h_k", [(64, 1, 2), (96, 2, 2), (128, 4, 1)])
def test_selected_kernel_shapes(kernel, n, g, h_k):
    q, k, v, idx, valid, cfg = make_inputs(
        jax.random.PRNGKey(0), n, g * h_k, h_k, 32, 32, 4, 16, jnp.float32)
    out = selected_attention(q, k, v, idx, valid, cfg, kernel=kernel)
    oracle = ref.selected_ref(q, k, v, idx, valid, cfg)
    np.testing.assert_allclose(out, oracle, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("kernel", KERNELS)
def test_selected_kernel_dk_ne_dv(kernel):
    q, k, v, idx, valid, cfg = make_inputs(
        jax.random.PRNGKey(1), 64, 4, 2, 24, 16, 3, 16, jnp.float32)
    out = selected_attention(q, k, v, idx, valid, cfg, kernel=kernel)
    oracle = ref.selected_ref(q, k, v, idx, valid, cfg)
    np.testing.assert_allclose(out, oracle, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("kernel", KERNELS)
def test_selected_kernel_bf16(kernel):
    q, k, v, idx, valid, cfg = make_inputs(
        jax.random.PRNGKey(2), 64, 4, 2, 32, 32, 4, 16, jnp.bfloat16)
    out = selected_attention(q, k, v, idx, valid, cfg, kernel=kernel)
    oracle = ref.selected_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), idx, valid, cfg)
    np.testing.assert_allclose(out.astype(jnp.float32), oracle, atol=3e-2,
                               rtol=3e-2)


@pytest.mark.parametrize("kernel", KERNELS)
@pytest.mark.parametrize("b_k,t_sel", [(16, 2), (32, 4)])
def test_selected_kernel_block_sizes(kernel, b_k, t_sel):
    q, k, v, idx, valid, cfg = make_inputs(
        jax.random.PRNGKey(3), 128, 2, 1, 32, 32, t_sel, b_k, jnp.float32)
    out = selected_attention(q, k, v, idx, valid, cfg, kernel=kernel)
    oracle = ref.selected_ref(q, k, v, idx, valid, cfg)
    np.testing.assert_allclose(out, oracle, atol=2e-5, rtol=2e-5)


def test_fsa_matches_faithful_bitwise_semantics():
    """The one-kernel TPU form and the three-kernel paper form agree."""
    q, k, v, idx, valid, cfg = make_inputs(
        jax.random.PRNGKey(4), 96, 4, 2, 32, 32, 4, 16, jnp.float32)
    o1 = selected_attention(q, k, v, idx, valid, cfg, kernel="fsa")
    o2 = selected_attention(q, k, v, idx, valid, cfg, kernel="fsa_faithful")
    np.testing.assert_allclose(o1, o2, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 24)])
def test_flash_kernel(causal, window):
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    n, h, h_k, d = 96, 4, 2, 32
    q = jax.random.normal(ks[0], (n, h, d))
    k = jax.random.normal(ks[1], (n, h_k, d))
    v = jax.random.normal(ks[2], (n, h_k, d))
    cfg = NSAConfig(q_block_size=32)
    if window is None:
        out = ops.full_attention(q, k, v, cfg, causal=causal)
    else:
        out = ops.sliding_attention(q, k, v, window, cfg)
    oracle = ref.flash_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, oracle, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("kernel", KERNELS)
def test_selected_kernel_ragged_n(kernel):
    """N not a multiple of the KV block: the trailing partial block must be
    masked by the logical seq_len, not read out of bounds (interpret mode
    pads OOB reads with NaN, and 0·NaN would poison the p@v accumulation)."""
    q, k, v, idx, valid, cfg = make_inputs(
        jax.random.PRNGKey(8), 100, 2, 2, 32, 32, 4, 16, jnp.float32)
    out = selected_attention(q, k, v, idx, valid, cfg, kernel=kernel)
    oracle = ref.selected_ref(q, k, v, idx, valid, cfg)
    np.testing.assert_allclose(out, oracle, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("kernel", ["fsa", "fsa_faithful"])
@pytest.mark.parametrize("n,g,h_k,dk,dv", [(64, 2, 1, 16, 16),
                                           (100, 1, 2, 32, 24)])
def test_selected_gradients_match_oracle(kernel, n, g, h_k, dk, dv):
    """Fused Pallas backward (dQ via union lists, dK/dV via occurrence
    lists) vs grad of the dense selected oracle — incl. ragged N and
    dk != dv, for both fused-backward kernel organizations."""
    q, k, v, idx, valid, cfg = make_inputs(
        jax.random.PRNGKey(6), n, g * h_k, h_k, dk, dv, 3, 16, jnp.float32)

    def f(q, k, v):
        return (selected_attention(q, k, v, idx, valid, cfg,
                                   kernel=kernel) ** 2).sum()

    def f_ref(q, k, v):
        return (ref.selected_ref(q, k, v, idx, valid, cfg) ** 2).sum()

    grads = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(grads, g_ref):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-5)


def test_selected_lse_residual_consistent_across_kernels():
    """The (out, lse) residual the fused backward consumes: the one-kernel
    FSA form and the three-kernel paper form emit identical lse panels, and
    maskless rows carry the +1e30 sentinel so exp(s - lse) underflows to 0."""
    from repro.attention import backends as ab
    q, k, v, idx, valid, cfg = make_inputs(
        jax.random.PRNGKey(9), 64, 2, 2, 16, 16, 3, 16, jnp.float32)
    outs, lses = {}, {}
    for kernel in ("fsa", "fsa_faithful"):
        out, res = ab._selected_run((cfg, kernel), q, k, v, idx, valid,
                                    want_lse=True)
        outs[kernel], lses[kernel] = out, res[1]
    np.testing.assert_allclose(lses["fsa"], lses["fsa_faithful"],
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(outs["fsa"], outs["fsa_faithful"],
                               atol=1e-5, rtol=1e-5)
    # token 0 of each KV head attends only key 0 (always selected block 0):
    # its lse must be finite; a row with an all-invalid selection gets +1e30
    idx0 = jnp.zeros((64, 2, 3), jnp.int32)
    valid0 = jnp.zeros((64, 2, 3), bool)
    _, res0 = ab._selected_run((cfg, "fsa"), q, k, v, idx0, valid0,
                               want_lse=True)
    assert np.all(np.asarray(res0[1]) >= 1e29)


@pytest.mark.parametrize("causal,window,n", [(True, None, 96),
                                             (True, None, 100),
                                             (False, None, 96),
                                             (True, 24, 96)])
def test_flash_gradients_match_oracle(causal, window, n):
    """Fused flash backward (dq/dkv kernels, recomputed from (out, lse)) vs
    grad of the dense oracle — full, non-causal, sliding, and ragged N."""
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    h, h_k, d = 4, 2, 32
    q = jax.random.normal(ks[0], (n, h, d))
    k = jax.random.normal(ks[1], (n, h_k, d))
    v = jax.random.normal(ks[2], (n, h_k, d))
    cfg = NSAConfig(q_block_size=32)

    def f(q, k, v):
        if window is None:
            out = ops.full_attention(q, k, v, cfg, causal=causal)
        else:
            out = ops.sliding_attention(q, k, v, window, cfg)
        return (out ** 2).sum()

    def f_ref(q, k, v):
        return (ref.flash_ref(q, k, v, causal=causal, window=window) ** 2).sum()

    grads = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(grads, g_ref):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-5)


def test_empty_selection_rows_are_zero():
    """Tokens whose selection is entirely invalid produce zero output."""
    n, h, h_k, d = 32, 2, 1, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (n, h, d))
    k = jax.random.normal(ks[1], (n, h_k, d))
    v = jax.random.normal(ks[2], (n, h_k, d))
    idx = jnp.zeros((n, h_k, 2), jnp.int32)
    valid = jnp.zeros((n, h_k, 2), bool)
    cfg = NSAConfig(block_size=16, q_block_size=16)
    out = selected_attention(q, k, v, idx, valid, cfg, kernel="fsa")
    np.testing.assert_allclose(out, jnp.zeros_like(out), atol=1e-6)
