"""Prefix caching: ref-counted page pool, radix-trie match/insert/evict,
copy-on-write at the compressed boundary page, write-floor routing, and
engine-level correctness — slots aliasing shared physical prefix pages must
decode exactly their dense-reference tokens, before and after the co-shared
slot is released (the page-table permutation-invariance guarantee extended
to shared tables)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core.paging import scatter_rows
from repro.models import build
from repro.serving import (Engine, PagePool, PagedNSACache, PrefixCache,
                           Request)

jax.config.update("jax_platform_name", "cpu")

MAX_LEN = 96
CHUNK = 32
P = 16                                   # reduced-config nsa.block_size


def _cfg(**over):
    return reduced(get_config("codeqwen1.5-7b"), **over)


def _dense_greedy(cfg, params, prompt, max_new, max_len=MAX_LEN):
    model = build(cfg)
    cache = model.init_cache(1, max_len)
    batch = {"tokens": jnp.asarray(prompt)[None],
             "labels": jnp.full((1, len(prompt)), -100)}
    logits, cache = jax.jit(model.prefill)(params, cache, batch)
    toks = [int(jnp.argmax(logits[0, :cfg.vocab]))]
    step = jax.jit(model.decode_step)
    for i in range(max_new - 1):
        logits, cache = step(params, cache, jnp.asarray([toks[-1]]),
                             jnp.asarray([len(prompt) + i]))
        toks.append(int(jnp.argmax(logits[0, :cfg.vocab])))
    return toks


def _prompt(seed, n, vocab):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                                         vocab))


# ------------------------------------------------------------- refcounts
def test_page_pool_refcounts():
    pool = PagePool(num_pages=8, page_size=16)
    lease = pool.try_alloc(2)
    pages = lease.take()
    assert [pool.refcount(p) for p in pages] == [1, 1]
    pool.share(pages)
    assert [pool.refcount(p) for p in pages] == [2, 2]
    pool.release(pages)                  # one ref down: still allocated
    assert pool.available == 5 and pool.refcount(pages[0]) == 1
    pool.release(pages)                  # last ref: pages return to the pool
    assert pool.available == 7 and pool.refcount(pages[0]) == 0
    with pytest.raises(ValueError):
        pool.release(pages)              # no live refs left
    with pytest.raises(ValueError):
        pool.share([pages[0]])           # sharing a freed page
    with pytest.raises(ValueError):
        pool.share([7])                  # never-allocated page


# ------------------------------------------------------------ radix trie
def _host_prefilled_cache(cfg, prompt, slot=0):
    """A PagedNSACache with ``slot`` allocated and marked fully prefilled
    (host bookkeeping only — trie tests don't touch page contents)."""
    cache = PagedNSACache(cfg, n_slots=2, max_len=MAX_LEN)
    prefix = PrefixCache(cache)
    cache.prefix = prefix
    cap = max(-(-len(prompt) // CHUNK) * CHUNK, len(prompt) + 4)
    assert cache.alloc_slot(slot, cap)
    cache.lengths[slot] = len(prompt)
    return cache, prefix


def test_trie_match_caps_and_aliases():
    """match() returns the longest cached block prefix, capped so at least
    one prompt token is always prefilled, with the donor's physical pages."""
    cfg = _cfg()
    prompt = _prompt(0, 80, cfg.vocab)
    cache, prefix = _host_prefilled_cache(cfg, prompt)
    assert prefix.insert(prompt, 0) == 80 // P           # 5 blocks indexed
    assert prefix.blocks_cached == 5

    m = prefix.match(prompt)             # identical prompt: cap applies
    assert m.tokens == ((80 - 1) // P) * P == 64         # 4, not 5 blocks
    assert m.raw_pages == cache.tables[0].pages[:4]      # physical aliases
    assert all(cache.pool.refcount(p) == 3 for p in m.raw_pages)
    m.cancel()                           # slot ref + trie ref remain
    assert all(cache.pool.refcount(p) == 2 for p in m.raw_pages)

    longer = np.concatenate([prompt, _prompt(1, 16, cfg.vocab)])
    m2 = prefix.match(longer)            # full 5 cached blocks now usable
    assert m2.tokens == 80
    m2.cancel()
    assert prefix.match(_prompt(2, 40, cfg.vocab)) is None   # diverges at 0
    assert prefix.match(prompt[:P]) is None                  # cap -> 0 blocks


def test_trie_shared_pages_survive_slot_release_until_evicted():
    cfg = _cfg()
    prompt = _prompt(3, 48, cfg.vocab)
    cache, prefix = _host_prefilled_cache(cfg, prompt)
    prefix.insert(prompt, 0)
    cached_raw = [n.raw_page for n in prefix._walk(prompt, 3)]
    cache.free_slot(0)
    # trie refs keep the cached blocks alive past the slot's release
    assert cache.pool.used == len(cached_raw) == 3
    assert prefix.evict_lru(prefix.blocks_cached) == 3
    assert cache.pool.used == 0 and cache.cmp_pool.used == 0
    assert prefix.blocks_cached == 0


def test_trie_lru_eviction_order():
    """evict_lru drops the least-recently-MATCHED leaf first."""
    cfg = _cfg()
    a = _prompt(4, 48, cfg.vocab)
    b = _prompt(5, 48, cfg.vocab)
    cache, prefix = _host_prefilled_cache(cfg, a)
    prefix.insert(a, 0)
    cap = max(-(-len(b) // CHUNK) * CHUNK, len(b) + 4)
    assert cache.alloc_slot(1, cap)
    cache.lengths[1] = len(b)
    prefix.insert(b, 1)
    a_leaf = prefix._walk(a, 3)[-1]
    prefix.match(np.concatenate([a, a[:8]])).cancel()     # touch chain a
    assert prefix.evict_lru(1) == 1                       # b's leaf goes
    assert prefix._walk(b, 3) != [] and len(prefix._walk(b, 3)) == 2
    assert prefix._walk(a, 3)[-1] is a_leaf               # a intact


# ------------------------------------------------------- write routing
def test_scatter_rows_min_pos_routes_to_dump_page():
    pool = jnp.zeros((4, 4, 2))
    table = jnp.asarray([[1, 2], [3, 1]], jnp.int32)
    positions = jnp.asarray([[0, 5], [0, 5]], jnp.int32)
    values = jnp.ones((2, 2, 2))
    out = scatter_rows(pool, table, positions, values,
                       min_pos=jnp.asarray([4, 0], jnp.int32))
    # slot 0's pos 0 is below its floor -> dumped; everything else lands
    assert float(out[1, 0].sum()) == 0          # page 1 row 0 (slot 0 pos 0)
    assert float(out[2, 1].sum()) == 2          # slot 0 pos 5 (above floor)
    assert float(out[3, 0].sum()) == 2          # slot 1 pos 0 (floor 0)
    assert float(out[1, 1].sum()) == 2          # slot 1 pos 5


# ----------------------------------------------------- engine-level CoW
def test_shared_tables_decode_identical_to_private_before_and_after_release():
    """Two slots aliasing the same physical prefix pages must decode exactly
    the tokens of fully private copies (= the dense reference), and shared
    page CONTENTS must stay byte-identical through the sharers' prefill and
    decode — including after one sharing slot is released mid-run."""
    cfg = _cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shared = _prompt(10, 48, cfg.vocab)
    pa = np.concatenate([shared, _prompt(11, 9, cfg.vocab)])
    pb = np.concatenate([shared, _prompt(12, 7, cfg.vocab)])
    pc = np.concatenate([shared, _prompt(13, 5, cfg.vocab)])

    eng = Engine(cfg, n_slots=2, max_len=MAX_LEN, prefill_chunk=CHUNK,
                 params=params, prefix_cache=True)
    donor = eng.submit(pa, max_new=2)
    while donor.state != "done":                     # warm the trie
        eng.step()
    trie_raw = [n.raw_page for n in eng._prefix._walk(shared, 3)]
    assert len(trie_raw) == 3 and eng.cache.pool.used >= 3

    rb = eng.submit(pb, max_new=8)
    rc = eng.submit(pc, max_new=2)
    eng.step()                                       # both admitted, matched
    assert rb.cached_tokens == 48 and rc.cached_tokens == 48
    sb, sc = rb.slot, rc.slot
    assert eng.cache.tables[sb].pages[:3] == trie_raw    # physical aliasing
    assert eng.cache.tables[sc].pages[:3] == trie_raw
    assert eng.cache.tables[sb].shared == 3
    layer0 = lambda: jax.tree.map(lambda a: np.asarray(a[0]),
                                  eng.cache.data["layers"])
    before = layer0()["k_pages"][trie_raw].copy()

    while rc.state != "done":                        # rc releases first
        eng.step()
    assert rb.state == "active"                      # rb still decoding
    np.testing.assert_array_equal(before, layer0()["k_pages"][trie_raw])
    eng.run()
    np.testing.assert_array_equal(before, layer0()["k_pages"][trie_raw])

    for req, prompt in ((donor, pa), (rb, pb), (rc, pc)):
        ref = _dense_greedy(cfg, params, prompt, req.max_new)
        assert list(req.out) == ref, f"rid {req.rid} diverged"
    s = eng.summary()
    assert s["prefix_hit_rate"] > 0 and s["prefix_blocks_reused"] == 6


def test_cow_boundary_cmp_page_is_private():
    """Full compressed pages are aliased; the partially-filled boundary
    compressed page is copy-on-write — the matcher gets its own physical
    page (its prefill appends rows there) and still matches dense."""
    cfg = _cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    shared = _prompt(20, 80, cfg.vocab)              # ncmp(80)=19: 1 full page
    pa = np.concatenate([shared, _prompt(21, 5, cfg.vocab)])
    pb = np.concatenate([shared, _prompt(22, 3, cfg.vocab)])

    eng = Engine(cfg, n_slots=2, max_len=MAX_LEN, prefill_chunk=CHUNK,
                 params=params, prefix_cache=True)
    donor = eng.submit(pa, max_new=2)
    while donor.state != "done":
        eng.step()
    chain = eng._prefix._walk(shared, 5)
    assert len(chain) == 5
    full_cmp = [pg for n in chain for pg in n.cmp_full_new]
    boundary = chain[-1].cmp_boundary
    assert len(full_cmp) == 1 and boundary is not None

    rb = eng.submit(pb, max_new=2)
    eng.step()
    assert rb.cached_tokens == 80
    ct = eng.cache.cmp_tables[rb.slot]
    assert ct.pages[0] == full_cmp[0] and ct.shared == 1    # aliased
    assert ct.pages[1] != boundary                   # CoW: private copy
    eng.run()
    assert list(rb.out) == _dense_greedy(cfg, params, pb, 2)


def test_eviction_under_pressure_admits_unrelated_request():
    """When the pools can't cover an admission, LRU cached prefixes are
    evicted (trie refs dropped) instead of failing the admission."""
    cfg = _cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(2))
    cache_probe = PagedNSACache(cfg, n_slots=1, max_len=MAX_LEN)
    num_pages = cache_probe.max_pages + 1            # exactly one slot's worth
    pa = _prompt(30, 80, cfg.vocab)
    pb = _prompt(31, 80, cfg.vocab)                  # unrelated prompt

    eng = Engine(cfg, n_slots=1, max_len=MAX_LEN, prefill_chunk=CHUNK,
                 params=params, num_pages=num_pages, prefix_cache=True)
    ra = eng.submit(pa, max_new=2)
    eng.run()
    assert eng._prefix.blocks_cached == 5
    assert eng.cache.pool.used == 5                  # trie refs only
    rb = eng.submit(pb, max_new=2)                   # needs the whole pool
    eng.run()
    assert rb.state == "done"
    assert eng._prefix.blocks_cached == 5            # pb's blocks replaced pa's
    assert eng._prefix._walk(pa, 5) == []            # pa's chain evicted
    assert list(ra.out) == _dense_greedy(cfg, params, pa, 2)
    assert list(rb.out) == _dense_greedy(cfg, params, pb, 2)


def test_prefix_cache_exact_parity_and_page_savings():
    """Prefix cache on vs off over the same prompts: identical tokens, hit
    counters advance, and fewer distinct raw pages are touched."""
    cfg = _cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(3))
    shared = _prompt(40, 48, cfg.vocab)
    prompts = [np.concatenate([shared, _prompt(41 + i, 6 + i, cfg.vocab)])
               for i in range(4)]

    outs, peaks = {}, {}
    for on in (False, True):
        eng = Engine(cfg, n_slots=2, max_len=MAX_LEN, prefill_chunk=CHUNK,
                     params=params, prefix_cache=on)
        reqs = [eng.submit(p, max_new=3) for p in prompts]
        s = eng.run()
        outs[on] = [list(r.out) for r in reqs]
        peaks[on] = s["peak_page_util"]
        if on:
            assert s["prefix_hit_rate"] > 0
            assert s["prefix_blocks_reused"] >= 3
            assert s["prefix_blocks_cached"] > 0
            assert eng.cache.pool.used > 0           # trie refs post-drain
            eng._prefix.clear()
            assert eng.cache.pool.used == 0
        else:
            assert s["prefix_hit_rate"] == 0
            assert eng.cache.pool.used == 0
    assert outs[True] == outs[False]
    assert peaks[True] <= peaks[False]


def test_cache_reset_clears_prefix_cache():
    cfg = _cfg()
    prompt = _prompt(50, 48, cfg.vocab)
    cache, prefix = _host_prefilled_cache(cfg, prompt)
    prefix.insert(prompt, 0)
    cache.reset()
    assert prefix.blocks_cached == 0
    assert cache.pool.used == 0 and cache.cmp_pool.used == 0
