"""repro.telemetry: metrics core (counters/gauges/histograms, exposition,
disabled-mode no-ops), spans (nesting, timing monotonicity, JSONL events),
attention-dispatch accounting, per-request serving timelines and bounded
retention."""
import json
import time

import pytest

import jax

from repro import telemetry
from repro.attention import (AttentionRequest, BackendResolutionError,
                             NSAConfig, explain, near_misses, nsa_attention,
                             resolve)
from repro.configs import get_config, reduced
from repro.serving import Engine, Request
from repro.serving.async_engine import AsyncEngine

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(autouse=True)
def _global_telemetry_reset():
    """Global telemetry is process state: leave every test with it off and
    empty, the way the process starts."""
    yield
    telemetry.disable()
    telemetry.registry().clear()


# ------------------------------------------------------------ metrics core
def test_counter_gauge_histogram_basics():
    reg = telemetry.Registry(enabled=True, name="t")
    reg.counter("req_total", backend="fsa").inc()
    reg.counter("req_total", backend="fsa").inc(2)    # get-or-create: same series
    reg.counter("req_total", backend="ref").inc()
    reg.gauge("depth").set(3)
    reg.gauge("depth").set(1)
    reg.histogram("lat_ms", buckets=(1.0, 5.0)).observe(0.5)
    reg.histogram("lat_ms", buckets=(1.0, 5.0)).observe(7.0)

    snap = reg.snapshot()
    assert telemetry.counter_value(snap, "req_total", backend="fsa") == 3
    assert telemetry.counter_value(snap, "req_total", backend="ref") == 1
    assert telemetry.counter_value(snap, "req_total", backend="nope") == 0
    g = telemetry.gauge_stats(snap, "depth")
    assert (g["last"], g["min"], g["max"], g["samples"]) == (1, 1, 3, 2)
    h = snap["histograms"]["lat_ms"][""]
    assert h["count"] == 2 and h["sum"] == 7.5
    assert h["buckets"] == {"1.0": 1, "5.0": 1, "+Inf": 2}   # cumulative


def test_disabled_registry_is_noop():
    reg = telemetry.Registry(enabled=False)
    c = reg.counter("x")
    assert c is telemetry.NOOP
    c.inc()
    reg.gauge("y").set(5)
    reg.histogram("z").observe(1.0)
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}
    assert reg.exposition() == ""


def test_global_registry_disabled_by_default():
    assert not telemetry.enabled()
    assert telemetry.registry().counter("anything") is telemetry.NOOP
    telemetry.enable()
    assert telemetry.enabled()
    assert telemetry.registry().counter("anything") is not telemetry.NOOP


def test_exposition_golden():
    reg = telemetry.Registry(enabled=True, name="t")
    reg.counter("req_total", backend="fsa").inc(3)
    reg.gauge("depth").set(2)
    h = reg.histogram("lat_ms", buckets=(1.0, 5.0), op="x")
    for v in (0.5, 3.0, 7.0):
        h.observe(v)
    assert reg.exposition() == (
        '# TYPE req_total counter\n'
        'req_total{backend="fsa"} 3\n'
        '# TYPE depth gauge\n'
        'depth 2\n'
        '# TYPE lat_ms histogram\n'
        'lat_ms_bucket{op="x",le="1.0"} 1\n'
        'lat_ms_bucket{op="x",le="5.0"} 2\n'
        'lat_ms_bucket{op="x",le="+Inf"} 3\n'
        'lat_ms_sum{op="x"} 10.5\n'
        'lat_ms_count{op="x"} 3\n')


# ------------------------------------------------------------------- spans
def test_span_nesting_and_timing_monotonicity():
    reg = telemetry.Registry(enabled=True, name="t")
    with telemetry.span("outer", registry=reg):
        time.sleep(0.002)
        with telemetry.span("inner", registry=reg):
            time.sleep(0.002)
    snap = reg.snapshot()
    spans = snap["histograms"]["span_ms"]
    outer = spans['span="outer"']
    inner = spans['span="inner"']
    assert outer["count"] == 1 and inner["count"] == 1
    # the outer span strictly contains the inner one
    assert outer["sum"] > inner["sum"] > 0


def test_span_noop_when_nothing_enabled():
    # global off, no explicit registry, no sink: the span must not record
    with telemetry.span("dead") as sp:
        sp.annotate(n=1)
    assert telemetry.registry().snapshot()["histograms"] == {}


def test_span_events_carry_depth_and_parent(tmp_path):
    path = str(tmp_path / "events.jsonl")
    telemetry.enable(jsonl=path)
    with telemetry.span("outer"):
        with telemetry.span("inner", stage="x") as sp:
            sp.annotate(items=7)
    telemetry.disable()
    events = [json.loads(line) for line in open(path)]
    spans = {e["name"]: e for e in events if e["kind"] == "span"}
    assert spans["inner"]["parent"] == "outer"
    assert spans["inner"]["depth"] == 1 and spans["outer"]["depth"] == 0
    assert spans["inner"]["stage"] == "x" and spans["inner"]["items"] == 7
    assert spans["outer"]["ms"] >= spans["inner"]["ms"]
    # annotate() fields are event-only: the histogram key stays bounded
    lk = 'span="inner",stage="x"'
    assert lk in telemetry.registry().snapshot()["histograms"]["span_ms"]


# ----------------------------------------------------- dispatch accounting
_CFG = NSAConfig(block_size=16, num_selected=4, cmp_block_size=8,
                 cmp_stride=4, window_size=32, q_block_size=32,
                 min_seq_for_sparse=1)


def _full_qkv(n=32, g=1, h_k=2, d=8):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    return (jax.random.normal(ks[0], (n, g * h_k, d)),
            jax.random.normal(ks[1], (n, h_k, d)),
            jax.random.normal(ks[2], (n, h_k, d)))


def test_dispatch_counter_once_per_call():
    telemetry.enable()
    q, k, v = _full_qkv()
    for _ in range(2):      # eager: one python call = one dispatch
        nsa_attention(None, None, q, k, v, cfg=_CFG, mode="prefill",
                      backend="reference", algorithm="full")
    snap = telemetry.registry().snapshot()
    assert telemetry.counter_value(
        snap, "attention_dispatch_total", backend="reference", mode="prefill",
        algorithm="full") == 2
    # and the dispatch shows up as a named span
    assert ('backend="reference",mode="prefill",span="attention.dispatch"'
            in snap["histograms"]["span_ms"])


def test_resolve_fallback_counter():
    telemetry.enable()
    cfg = NSAConfig(block_size=16, num_selected=4, cmp_block_size=8,
                    cmp_stride=4, window_size=32, q_block_size=32,
                    min_seq_for_sparse=4096)
    req = AttentionRequest(mode="prefill", algorithm="nsa", seq_len=64, g=2)
    assert resolve(cfg, req, "fsa").name == "reference"   # dense fallback
    snap = telemetry.registry().snapshot()
    assert telemetry.counter_value(
        snap, "attention_resolve_fallback_total", kind="dense_short_seq",
        mode="prefill") == 1


# --------------------------------------------------------- explain / misses
def test_explain_prints_capability_table():
    req = AttentionRequest(mode="prefill", algorithm="nsa", seq_len=256, g=2)
    text = explain(_CFG, req)
    assert "resolve -> " in text
    assert "reference" in text and "fsa" in text
    assert "OK" in text and "score=" in text


def test_near_misses_in_resolution_error(monkeypatch):
    # the dense reference backend covers every request, so an unservable one
    # only exists without it: differentiable paged training — paged backends
    # are inference-only, the rest do not read paged KV.  The error must
    # name the nearest misses instead of a bare failure.
    from repro.attention import registry as areg
    monkeypatch.setattr(areg, "_REGISTRY", {
        n: b for n, b in areg._REGISTRY.items() if n != "reference"})
    req = AttentionRequest(mode="train", algorithm="nsa", paged=True,
                           needs_grad=True, g=2)
    assert near_misses(req)
    with pytest.raises(BackendResolutionError, match="Nearest misses"):
        resolve(None, req, "auto")
    text = explain(None, req)
    assert "FAILS" in text


# ------------------------------------------------- serving timelines/spans
def test_engine_timelines_spans_and_retention():
    cfg = reduced(get_config("codeqwen1.5-7b"))
    eng = Engine(cfg, n_slots=2, max_len=96, prefill_chunk=32,
                 retain_outputs=1)
    t_before = time.time()
    for prompt_len in (40, 8, 12):
        eng.submit(list(range(1, prompt_len + 1)), max_new=2)
    summary = eng.run()

    assert summary["requests_finished"] == 3
    finished = eng.scheduler.finished
    for r in finished:
        tl = r.timeline()
        # submit <= admit <= first_chunk <= first_token <= finish, all stamped
        keys = list(tl)
        assert keys == ["submit", "admit", "first_chunk", "first_token",
                        "finish"]
        stamps = list(tl.values())
        assert stamps == sorted(stamps)
        assert stamps[0] >= t_before
    # bounded retention: only the newest finished request keeps its tokens
    evicted = [r for r in finished if r.out_evicted]
    kept = [r for r in finished if not r.out_evicted]
    assert len(kept) == 1 and len(evicted) == 2
    for r in evicted:
        assert r.out == [] and r.num_out == 2 and r.prompt_len > 0
        assert r.timeline()     # timeline survives eviction
    assert set(summary["outputs"]) == {kept[0].rid}
    assert set(eng.timelines()) == {r.rid for r in finished}

    # every tick phase is a named span in the engine's telemetry snapshot
    snap = eng.telemetry.snapshot()
    span_keys = "".join(snap["histograms"]["span_ms"])
    for phase in ("engine.tick", "engine.admit", "engine.prefill_chunk",
                  "engine.host_sync"):
        assert phase in span_keys, phase
    # legacy stats keys stay derivable from the snapshot; with max_new=2
    # each request yields one prefill-materialized token + one decoded token
    stats = eng.stats
    assert stats["decoded_tokens"] == summary["decoded_tokens"] == 3
    assert stats["prefill_tokens"] == 40 + 8 + 12
    assert summary["peak_page_util"] > 0


def test_async_engine_timeline_retention_bounded():
    cfg = reduced(get_config("codeqwen1.5-7b"))
    aeng = AsyncEngine(Engine(cfg, n_slots=2, max_len=96, prefill_chunk=32),
                       retain_timelines=2)
    # exercise the retention bookkeeping directly (no event loop needed:
    # _on_finish is the engine-thread hook)
    reqs = [Request(prompt=[1, 2, 3]) for _ in range(3)]
    for r in reqs:
        r.admit_t = r.first_token_t = r.finish_t = r.submit_t
        aeng._on_finish(r)
    assert aeng.timeline(reqs[0].rid) is None          # evicted past the cap
    assert set(aeng.timelines()) == {reqs[1].rid, reqs[2].rid}
    tl = aeng.timeline(reqs[2].rid)
    assert tl["submit"] <= tl["first_token"] <= tl["finish"]


# --------------------------------------------------------- pull endpoint
def test_metrics_pull_endpoint_serves_engine_registry():
    """Engine(metrics_port=0) exposes the engine's always-on registry as a
    Prometheus /metrics endpoint on an ephemeral port."""
    import urllib.request

    import numpy as np

    cfg = reduced(get_config("codeqwen1.5-7b"))
    eng = Engine(cfg, n_slots=1, max_len=64, metrics_port=0)
    try:
        assert eng.metrics_server is not None
        eng.submit(np.arange(8, dtype=np.int32), max_new=2)
        while not eng.scheduler.idle():
            eng.step()
        body = urllib.request.urlopen(eng.metrics_server.url,
                                      timeout=10).read().decode()
        assert "engine_decoded_tokens_total" in body
        assert "# TYPE" in body                 # Prometheus text format
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                eng.metrics_server.url.replace("/metrics", "/nope"),
                timeout=10)
    finally:
        eng.metrics_server.stop()


def test_metrics_pull_endpoint_global_registry_late_enable():
    """A server bound to the global registry starts serving real series the
    moment telemetry.enable() runs (registry resolved per scrape)."""
    import urllib.request

    srv = telemetry.serve_metrics(0)
    try:
        before = urllib.request.urlopen(srv.url, timeout=10).read().decode()
        assert "pull_probe_total" not in before
        telemetry.enable()
        telemetry.registry().counter("pull_probe_total").inc(3)
        after = urllib.request.urlopen(srv.url, timeout=10).read().decode()
        assert "pull_probe_total 3" in after
    finally:
        srv.stop()


# ------------------------------------------------------- train-loop spans
def test_train_loop_records_train_step_spans(tmp_path):
    """launch.train wraps each optimizer step in a train.step span: with
    global telemetry on, span_ms series (device-synced) must appear."""
    from repro.launch.mesh import make_mesh
    from repro.launch.train import train_loop
    from repro.runtime.fault_tolerance import FTConfig

    telemetry.enable()
    cfg = reduced(get_config("mamba2-130m"))
    mesh = make_mesh((1, 1), ("data", "model"))
    ft = FTConfig(ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=0,
                  heartbeat_path=str(tmp_path / "hb.json"))
    _, losses = train_loop(cfg, steps=2, batch=2, seq=32, mesh=mesh, ft=ft,
                           quiet=True)
    assert len(losses) == 2
    spans = telemetry.registry().snapshot()["histograms"]["span_ms"]
    step_span = spans['span="train.step"']
    assert step_span["count"] == 2 and step_span["sum"] > 0
