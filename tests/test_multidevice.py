"""Multi-device tests (8 forced host devices, run in a subprocess so the
main pytest process keeps its single-device view)."""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import warnings; warnings.filterwarnings("ignore")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.launch.mesh import make_mesh, mesh_context

    # ---- collective matmul == all_gather + matmul ----
    from repro.parallel.collective_matmul import all_gather_matmul
    mesh = make_mesh((8,), ("model",))
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 48))
    with mesh_context(mesh):
        y = jax.jit(lambda x, w: all_gather_matmul(x, w, mesh))(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=2e-4, atol=2e-4)
    print("collective_matmul OK")

    # ---- pipeline forward == sequential layers ----
    from repro.parallel.pipeline import make_pipelined_backbone
    mesh_p = make_mesh((4,), ("pipe",))
    n_layers, d = 8, 16
    ws = jax.random.normal(jax.random.PRNGKey(2), (n_layers, d, d)) * 0.3
    block = lambda w, h: jnp.tanh(h @ w)
    xs = jax.random.normal(jax.random.PRNGKey(3), (4, 2, 8, d))  # (micro,B,S,D)
    ref = xs
    for i in range(n_layers):
        ref = jnp.tanh(ref @ ws[i])
    fn = make_pipelined_backbone(block, n_layers, 4, mesh_p)
    with mesh_context(mesh_p):
        out = jax.jit(fn)(ws, xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print("pipeline OK")

    # ---- sharded train step on a 2x4 mesh (FSDP x TP) ----
    from repro.configs import get_config, reduced
    from repro.launch.steps import make_train_step
    from repro.models import build
    from repro.models.registry import make_reduced_batch
    from repro.optim import AdamWConfig, init_opt_state
    from repro.parallel import partition
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = reduced(get_config("h2o-danube-3-4b"))
    mesh2 = make_mesh((2, 4), ("data", "model"))
    model = build(cfg)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh2, s), t,
                                is_leaf=lambda s: isinstance(s, P))
    with mesh_context(mesh2):
        params = model.init(jax.random.PRNGKey(0))
        pspecs = partition.param_specs(params, mesh2)
        from repro.optim import opt_state_specs
        state = {"params": params, "opt": init_opt_state(params, AdamWConfig())}
        sspecs = {"params": pspecs, "opt": opt_state_specs(pspecs, AdamWConfig())}
        state = jax.device_put(state, ns(sspecs))
        batch = make_reduced_batch(cfg, jax.random.PRNGKey(1), 4, 64)
        step = jax.jit(make_train_step(cfg, mesh2, AdamWConfig()),
                       in_shardings=(ns(sspecs), None),
                       out_shardings=(ns(sspecs), None), donate_argnums=(0,))
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
    print("sharded_train_step OK loss", float(metrics["loss"]))
""")


@pytest.mark.slow
def test_multidevice_suite():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                                        "HOME": "/root"}, cwd="/root/repo",
                       timeout=1200)
    assert "collective_matmul OK" in r.stdout, r.stdout + r.stderr
    assert "pipeline OK" in r.stdout, r.stdout + r.stderr
    assert "sharded_train_step OK" in r.stdout, r.stdout + r.stderr
