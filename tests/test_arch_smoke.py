"""Per-architecture smoke tests: reduced same-family config, one forward +
train step on CPU, asserting output shapes and no NaNs (assignment §f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_configs, reduced
from repro.models import build
from repro.models.registry import make_reduced_batch

ARCHS = sorted(all_configs())


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch, rng):
    cfg = reduced(all_configs()[arch])
    model = build(cfg)
    params = model.init(rng)
    batch = make_reduced_batch(cfg, jax.random.fold_in(rng, 1), batch=2, seq=64)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    assert float(loss) > 0

    # one SGD step: grads exist, are finite, and change the loss
    grads = jax.jit(jax.grad(lambda p: model.loss(p, batch)[0]))(params)
    gnorm = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda x: jnp.abs(x).sum(), grads))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"
    params2 = jax.tree.map(lambda p, g: p - 1e-2 * g.astype(p.dtype), params, grads)
    loss2, _ = jax.jit(model.loss)(params2, batch)
    assert not bool(jnp.isnan(loss2)), f"{arch}: NaN after step"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, rng):
    cfg = reduced(all_configs()[arch])
    model = build(cfg)
    params = model.init(rng)
    batch = make_reduced_batch(cfg, jax.random.fold_in(rng, 1), batch=2, seq=32)
    cache = model.init_cache(2, 64)
    logits, cache = jax.jit(model.prefill)(params, cache, batch)
    assert logits.shape == (2, cfg.vocab)
    logits2, cache = jax.jit(model.decode_step)(
        params, cache, jnp.array([1, 2]), jnp.array(32))
    assert logits2.shape == (2, cfg.vocab)
    assert not bool(jnp.isnan(logits2).any()), f"{arch}: NaN decode logits"
