"""NSA core module: sparse path vs dense oracle; decode vs full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (NSAConfig, apply_gates, compressed_and_selection,
                        init_nsa_params, nsa_attention, nsa_attention_ref,
                        nsa_attention_sparse, nsa_decode_step)
from repro.core import compression

CFG = NSAConfig(block_size=16, num_selected=4, cmp_block_size=8, cmp_stride=4,
                window_size=32, q_block_size=32, min_seq_for_sparse=1)


@pytest.fixture(scope="module")
def setup():
    N, h, hk, d, dm = 128, 4, 2, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    p = init_nsa_params(ks[0], dm, h, d, CFG)
    x = jax.random.normal(ks[1], (N, dm))
    q = jax.random.normal(ks[2], (N, h, d))
    k = jax.random.normal(ks[3], (N, hk, d))
    v = jax.random.normal(ks[4], (N, hk, d))
    return p, apply_gates(p, x), q, k, v


def test_sparse_matches_reference(setup):
    p, gates, q, k, v = setup
    o_ref = nsa_attention_ref(p, gates, q, k, v, CFG)
    for chunk in (32, 64, 128):
        o_sp = nsa_attention_sparse(p, gates, q, k, v, CFG, q_chunk=chunk)
        np.testing.assert_allclose(o_sp, o_ref, atol=2e-5, rtol=2e-5)


def test_kernel_impl_matches_reference(setup):
    p, gates, q, k, v = setup
    o_ref = nsa_attention_ref(p, gates, q, k, v, CFG)
    o_k = nsa_attention(p, gates, q, k, v, CFG, impl="kernel", q_chunk=64)
    np.testing.assert_allclose(o_k, o_ref, atol=2e-5, rtol=2e-5)


def test_decode_matches_full_forward(setup):
    """Decoding token t with caches == row t of the full forward pass."""
    p, gates, q, k, v = setup
    n = q.shape[0]
    o_full = nsa_attention_ref(p, gates, q, k, v, CFG)
    k_cmp, v_cmp = compression.compress_kv(p, k, v, CFG)
    for t in (40, 77, n - 1):
        o_t = nsa_decode_step(p, gates[t], q[t], k, v, k_cmp, v_cmp,
                              jnp.asarray(t), CFG)
        np.testing.assert_allclose(o_t, o_full[t], atol=3e-5, rtol=3e-5)


def test_selection_is_shared_across_group(setup):
    p, _, q, k, v = setup
    _, idx, valid = compressed_and_selection(p, q, k, v, CFG, q_chunk=64)
    assert idx.shape[1] == k.shape[1]          # per KV head, not per q head


def test_gates_bound(setup):
    _, gates, _, _, _ = setup
    assert float(gates.min()) >= 0 and float(gates.max()) <= 1


def test_compression_visibility():
    vis = compression.cmp_visibility(jnp.arange(32), 7, CFG)
    # token t sees cmp block j iff j*stride + block - 1 <= t
    for t in range(32):
        for j in range(7):
            assert bool(vis[t, j]) == (j * CFG.cmp_stride +
                                       CFG.cmp_block_size - 1 <= t)


def test_cmp_to_sel_map_partition():
    m = compression.cmp_to_sel_map(13, 4, CFG)
    # every compressed block's overlap weights sum to <= 1 (tail clipping)
    assert m.shape == (13, 4)
    assert (m.sum(1) <= 1.0 + 1e-6).all()
    assert (m >= 0).all()


def test_short_sequence_falls_back_to_reference():
    N, h, hk, d, dm = 32, 2, 1, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    p = init_nsa_params(ks[0], dm, h, d, CFG)
    gates = apply_gates(p, jax.random.normal(ks[1], (N, dm)))
    q = jax.random.normal(ks[2], (N, h, d))
    k = jax.random.normal(ks[3], (N, hk, d))
    v = jax.random.normal(ks[4], (N, hk, d))
    cfg = NSAConfig(**{**CFG.__dict__, "min_seq_for_sparse": 64})
    out = nsa_attention(p, gates, q, k, v, cfg, impl="sparse")
    ref = nsa_attention_ref(p, gates, q, k, v, cfg)
    np.testing.assert_allclose(out, ref, atol=1e-6)


def test_union_selected_matches_reference(setup):
    """FSA block-union XLA path (production) == dense oracle."""
    from repro.attention import nsa_attention as unified
    p, gates, q, k, v = setup
    o_ref = nsa_attention_ref(p, gates, q, k, v, CFG)
    o_u = unified(p, gates, q, k, v, cfg=CFG, mode="prefill",
                  backend="sparse_union", q_chunk=64)
    o_g = unified(p, gates, q, k, v, cfg=CFG, mode="prefill",
                  backend="sparse_gather", q_chunk=64)
    np.testing.assert_allclose(o_u, o_ref, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(o_g, o_ref, atol=2e-5, rtol=2e-5)
