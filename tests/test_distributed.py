"""Partitioning rules, mesh construction, serve engine, whisper decode."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.launch.mesh import make_mesh, mesh_context
from repro.models import build
from repro.parallel import partition
from repro.parallel.axes import axis_rules, resolve


def test_param_specs_rules():
    cfg = reduced(get_config("codeqwen1.5-7b"))
    model = build(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mesh = make_mesh((1, 1), ("data", "model"))
    specs = partition.param_specs(params, mesh)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    # every leaf got a spec of matching rank
    pflat = jax.tree_util.tree_flatten_with_path(params)[0]
    for (kp, spec), (_, leaf) in zip(flat, pflat):
        assert len(spec) <= leaf.ndim


def test_divisibility_filter_drops_nondividing_axes():
    mesh = make_mesh((1, 1), ("data", "model"))  # sizes 1 divide everything
    spec = partition._filter_spec(P("data", "model"), (4, 6), mesh)
    assert spec == P("data", "model")

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 16}

    spec = partition._filter_spec(P("data", "model"), (8, 24), FakeMesh())
    assert spec == P("data", None)  # 24 % 16 != 0 -> model dropped


def test_batch_specs_seq_fallback():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 8, "model": 2}

    batch = {"tokens": jax.ShapeDtypeStruct((1, 1024), jnp.int32)}
    specs = partition.batch_specs(batch, FakeMesh())
    assert specs["tokens"] == P(None, "data")  # B=1 -> shard the sequence


def test_axis_rules_override():
    with axis_rules({"seq_sp": None}):
        spec = resolve("batch", "seq_sp", "embed", shape=(8, 64, 32))
        assert spec[1] is None


def test_train_step_under_mesh_constraint_paths():
    """Exercise with_sharding_constraint paths on a real (1,1) mesh."""
    from repro.launch.steps import make_train_step
    from repro.optim import AdamWConfig, init_opt_state
    from repro.models.registry import make_reduced_batch

    cfg = reduced(get_config("olmoe-1b-7b"))
    mesh = make_mesh((1, 1), ("data", "model"))
    model = build(cfg)
    with mesh_context(mesh):
        params = model.init(jax.random.PRNGKey(0))
        state = {"params": params,
                 "opt": init_opt_state(params, AdamWConfig())}
        batch = make_reduced_batch(cfg, jax.random.PRNGKey(1), 4, 64)
        step = make_train_step(cfg, mesh, AdamWConfig(), num_microbatches=2)
        state, metrics = jax.jit(step, donate_argnums=(0,))(state, batch)
        assert np.isfinite(float(metrics["loss"]))


def test_serve_engine_end_to_end():
    from repro.launch.serve import Engine, Request

    cfg = reduced(get_config("h2o-danube-3-4b"))
    eng = Engine(cfg, batch_slots=2, max_len=96)
    reqs = [Request(i, jax.random.randint(jax.random.PRNGKey(i), (48,), 0,
                                          cfg.vocab), max_new=8)
            for i in range(2)]
    stats = eng.run(reqs, new_tokens=8)
    assert len(stats["outputs"][0]) == 8
    assert all(0 <= t < cfg.vocab for t in stats["outputs"][0])


def test_production_mesh_requires_512_devices():
    import pytest
    from repro.launch.mesh import make_production_mesh
    with pytest.raises(RuntimeError):
        make_production_mesh()  # only 1 device in the test process
