"""Loss-curve correctness (paper Fig. 10 analogue, CPU-fast version):
a tiny LM trains with NSA attention and the loss decreases; the FSA-kernel
implementation follows the same trajectory as the sparse reference path."""
import dataclasses

import numpy as np

from repro.configs import get_config, reduced
from repro.launch.mesh import make_mesh
from repro.launch.train import train_loop
from repro.runtime.fault_tolerance import FTConfig


def _run(cfg, steps, tmp, tag):
    mesh = make_mesh((1, 1), ("data", "model"))
    ft = FTConfig(ckpt_dir=str(tmp / f"ck_{tag}"), ckpt_every=0,
                  heartbeat_path=str(tmp / f"hb_{tag}.json"))
    _, losses = train_loop(cfg, steps=steps, batch=4, seq=128, mesh=mesh,
                           ft=ft, quiet=True)
    return losses


def test_nsa_loss_decreases(tmp_path):
    cfg = reduced(get_config("codeqwen1.5-7b"))
    losses = _run(cfg, 30, tmp_path, "nsa")
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.1, f"no learning: {first:.3f} -> {last:.3f}"


def test_kernel_impl_matches_sparse_losses(tmp_path):
    base = reduced(get_config("codeqwen1.5-7b"))
    cfg_sparse = dataclasses.replace(base, attn_impl="sparse")
    cfg_kernel = dataclasses.replace(base, attn_impl="kernel")
    l_sp = _run(cfg_sparse, 4, tmp_path, "sp")
    l_k = _run(cfg_kernel, 4, tmp_path, "k")
    np.testing.assert_allclose(l_sp, l_k, rtol=2e-3, atol=2e-3)


def test_full_attention_baseline_trains(tmp_path):
    cfg = dataclasses.replace(reduced(get_config("codeqwen1.5-7b")),
                              attention="full")
    losses = _run(cfg, 20, tmp_path, "full")
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
