"""Cross-backend equivalence suite, driven FROM the registry.

Every backend registered in ``repro.attention`` is compared against the
``reference`` backend for every mode it declares (train/prefill, decode,
paged-decode) — a backend added tomorrow is covered here with zero test
changes.  Also: the ``resolve`` contract (capability filtering, structured
errors naming alternatives, min-seq dense fallback, policy routing) and the
deprecation shims mapping the old config spellings.
"""
import dataclasses
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.attention import (AttentionRequest, BackendResolutionError,
                             KernelPolicy, NSAConfig, capable_backends,
                             get_backend, list_backends, nsa_attention,
                             resolve)
from repro.core import apply_gates, compression, init_nsa_params
from repro.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")

CFG = NSAConfig(block_size=16, num_selected=4, cmp_block_size=8, cmp_stride=4,
                window_size=32, q_block_size=32, min_seq_for_sparse=1)
N, H_K, D, DM = 96, 2, 16, 32


def _nsa_state(g, seed=0):
    h = g * H_K
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    p = init_nsa_params(ks[0], DM, h, D, CFG)
    gates = apply_gates(p, jax.random.normal(ks[1], (N, DM)))
    q = jax.random.normal(ks[2], (N, h, D))
    k = jax.random.normal(ks[3], (N, H_K, D))
    v = jax.random.normal(ks[4], (N, H_K, D))
    return p, gates, q, k, v


def _paged_state(seed=0, slots=3, g=2, max_pages=4, n_pages=24):
    p_sz = CFG.block_size
    h = H_K * g
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    perm = np.random.default_rng(seed).permutation(np.arange(1, n_pages))
    n_cmp = CFG.num_cmp_blocks(max_pages * p_sz)
    return {
        "q": jax.random.normal(ks[0], (slots, h, D)),
        "gates": jax.nn.softmax(jax.random.normal(ks[1], (slots, h, 3)), -1),
        "k_pages": jax.random.normal(ks[2], (n_pages, p_sz, H_K, D)),
        "v_pages": jax.random.normal(ks[3], (n_pages, p_sz, H_K, D)),
        "cmp_k": jax.random.normal(ks[4], (slots, n_cmp, H_K, D)),
        "cmp_v": jax.random.normal(ks[5], (slots, n_cmp, H_K, D)),
        "tables": jnp.asarray(perm[:slots * max_pages].reshape(slots,
                                                               max_pages),
                              jnp.int32),
        "pos": jnp.asarray(np.random.default_rng(seed + 1).integers(
            0, max_pages * p_sz, size=(slots,)), jnp.int32),
    }


# ----------------------------------------------------- registry-driven sweep
def _declared(mode, algorithm="nsa"):
    return sorted(n for n, c in list_backends().items()
                  if mode in c.modes and algorithm in c.algorithms)


@pytest.mark.parametrize("name", _declared("prefill"))
def test_backend_matches_reference_prefill(name):
    caps = list_backends()[name]
    g = max(2, caps.min_g)
    p, gates, q, k, v = _nsa_state(g)
    ref = nsa_attention(p, gates, q, k, v, cfg=CFG, mode="prefill",
                        backend="reference")
    out = nsa_attention(p, gates, q, k, v, cfg=CFG, mode="prefill",
                        backend=name)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5, err_msg=name)


@pytest.mark.parametrize("algorithm", ["full", "sliding"])
@pytest.mark.parametrize("name", sorted(
    set(_declared("prefill", "full")) | set(_declared("prefill", "sliding"))))
def test_backend_matches_oracle_full_sliding(name, algorithm):
    caps = list_backends()[name]
    if algorithm not in caps.algorithms:
        pytest.skip(f"{name} does not declare algorithm {algorithm}")
    _, _, q, k, v = _nsa_state(2)
    window = 24 if algorithm == "sliding" else None
    out = nsa_attention(None, None, q, k, v, cfg=CFG, mode="prefill",
                        backend=name, algorithm=algorithm, window=window)
    oracle = kref.flash_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=3e-5, rtol=3e-5, err_msg=name)


@pytest.mark.parametrize("name", _declared("decode"))
def test_backend_matches_reference_decode(name):
    p, gates, q, k, v = _nsa_state(2, seed=1)
    ck, cv = compression.compress_kv(p, k, v, CFG)
    for t in (37, N - 1):
        cache = {"cmp_k": ck, "cmp_v": cv, "pos": jnp.asarray(t)}
        ref = nsa_attention(p, gates[t], q[t], k, v, cache, cfg=CFG,
                            mode="decode", backend="reference")
        out = nsa_attention(p, gates[t], q[t], k, v, cache, cfg=CFG,
                            mode="decode", backend=name)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5, rtol=3e-5,
                                   err_msg=f"{name} pos={t}")


@pytest.mark.parametrize("name", _declared("paged_decode"))
def test_backend_matches_reference_paged_decode(name):
    st = _paged_state(seed=2)
    cache = {"page_tables": st["tables"], "cmp_k": st["cmp_k"],
             "cmp_v": st["cmp_v"], "pos": st["pos"]}
    args = (None, st["gates"], st["q"], st["k_pages"], st["v_pages"], cache)
    ref = nsa_attention(*args, cfg=CFG, mode="paged_decode",
                        backend="reference")
    out = nsa_attention(*args, cfg=CFG, mode="paged_decode", backend=name)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5, err_msg=name)


def test_every_backend_is_covered_by_some_sweep():
    """No registered backend escapes the equivalence sweeps above."""
    covered = set(_declared("prefill")) | set(_declared("decode")) \
        | set(_declared("paged_decode")) \
        | set(_declared("prefill", "full")) \
        | set(_declared("prefill", "sliding")) | {"reference"}
    assert covered == set(list_backends()), (
        f"backends outside the sweep: {set(list_backends()) - covered}")


# --------------------------------------------------------------- resolve()
def test_resolve_auto_defaults():
    cfg = CFG
    assert resolve(cfg, AttentionRequest(mode="train", seq_len=N,
                                         g=2)).name == "sparse_union"
    assert resolve(cfg, AttentionRequest(mode="decode",
                                         g=2)).name == "sparse_gather"
    assert resolve(cfg, AttentionRequest(mode="paged_decode", g=2,
                                         paged=True)).name == "paged_kernel"
    # TPU platform prefers the Pallas FSA kernel for train/prefill
    assert resolve(cfg, AttentionRequest(mode="train", seq_len=N, g=2,
                                         platform="tpu")).name == "fsa"


def test_resolve_prefers_fused_backward_for_training():
    """A train-mode request under jax.grad lands on a fused-backward
    backend (the Pallas backward kernels), not the XLA-twin paths — while
    inference-shaped requests (needs_grad=False, as in
    test_resolve_auto_defaults) keep the historic defaults."""
    req = AttentionRequest(mode="train", seq_len=N, g=2, needs_grad=True)
    assert resolve(CFG, req).name == "fsa"
    assert list_backends()["fsa"].fused_backward
    for algorithm, expect in (("full", "flash_full"),
                              ("sliding", "flash_sliding")):
        req = AttentionRequest(mode="train", algorithm=algorithm, seq_len=N,
                               g=2, needs_grad=True)
        assert resolve(CFG, req).name == expect
        assert list_backends()[expect].fused_backward
    # the bonus is train-only: prefill+needs_grad keeps the inference pick
    req = AttentionRequest(mode="prefill", seq_len=N, g=2, needs_grad=True)
    assert resolve(CFG, req).name == "sparse_union"


def test_resolve_min_seq_dense_fallback():
    cfg = dataclasses.replace(CFG, min_seq_for_sparse=256)
    assert resolve(cfg, AttentionRequest(mode="train", seq_len=64,
                                         g=2)).name == "reference"
    # explicit backends fall back too (old nsa_attention(impl=) semantics)
    assert resolve(cfg, AttentionRequest(mode="train", seq_len=64, g=2),
                   backend="sparse_union").name == "reference"


def test_resolve_policy_routing():
    cfg = dataclasses.replace(
        CFG, policy=KernelPolicy(backend="fsa_faithful",
                                 paged_backend="paged_gather",
                                 q_block_size=32))
    assert resolve(cfg, AttentionRequest(mode="train", seq_len=N,
                                         g=2)).name == "fsa_faithful"
    assert resolve(cfg, AttentionRequest(mode="paged_decode", g=2,
                                         paged=True)).name == "paged_gather"


def test_policy_nsa_backend_does_not_capture_full_sliding():
    """A policy naming an NSA selected-branch kernel must not hijack (and
    break) the full/sliding/cross-attention paths — the old cfg.kernel never
    affected them either."""
    cfg = dataclasses.replace(CFG, policy=KernelPolicy(backend="fsa",
                                                       q_block_size=32))
    assert resolve(cfg, AttentionRequest(mode="prefill", algorithm="full",
                                         seq_len=N, g=2)).name == "reference"
    _, _, q, k, v = _nsa_state(2, seed=5)
    out = nsa_attention(None, None, q, k, v, cfg=cfg, mode="prefill",
                        algorithm="sliding", window=24)
    oracle = kref.flash_ref(q, k, v, causal=True, window=24)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=3e-5, rtol=3e-5)


def test_resolve_structured_error_names_alternatives():
    req = AttentionRequest(mode="train", seq_len=N, g=2)
    with pytest.raises(BackendResolutionError) as e:
        resolve(CFG, req, backend="nsa")        # nsa declares min_g=8
    err = e.value
    assert err.requested == "nsa" and "min_g" in err.reason
    assert "sparse_union" in err.alternatives and "fsa" in err.alternatives
    assert "sparse_union" in str(err)
    # ...and g=8 makes it capable again
    assert resolve(CFG, AttentionRequest(mode="train", seq_len=N, g=8),
                   backend="nsa").name == "nsa"


def test_resolve_rejects_nondifferentiable_for_grad():
    req = AttentionRequest(mode="paged_decode", g=2, paged=True,
                           needs_grad=True)
    with pytest.raises(BackendResolutionError, match="not differentiable"):
        resolve(CFG, req, backend="paged_kernel")


def test_decode_modes_are_nsa_only():
    """full/sliding have no cache-decode path: the request is rejected up
    front with a structured error, never a shape crash inside a backend."""
    for mode in ("decode", "paged_decode"):
        with pytest.raises(BackendResolutionError, match="NSA-only"):
            resolve(CFG, AttentionRequest(mode=mode, algorithm="full", g=2,
                                          paged=(mode == "paged_decode")))


def test_policy_routes_paged_prefill_selected_branch():
    """sparse_selected_fn surfaces the policy's union/gather choice for code
    that runs the sparse chunk machinery directly (paged chunked prefill)."""
    from repro.attention import backends as ab
    from repro.core import sparse as core_sparse
    assert ab.sparse_selected_fn(CFG) is core_sparse.selected_union_attention
    cfg = dataclasses.replace(CFG,
                              policy=KernelPolicy(backend="sparse_gather"))
    assert ab.sparse_selected_fn(cfg) is core_sparse.selected_gather_attention


def test_unknown_backend_errors():
    with pytest.raises(KeyError, match="unknown attention backend"):
        get_backend("does_not_exist")


def test_capable_backends_filters():
    names = capable_backends(AttentionRequest(mode="paged_decode", g=2,
                                              paged=True))
    assert set(names) == {"paged_kernel", "paged_gather", "reference"}


def test_nsa_config_policy_passthrough_knobs():
    """Tuning-knob kwargs land on the policy; algorithm fields are intact."""
    cfg = NSAConfig(block_size=16, q_block_size=32, interpret=True)
    assert cfg.block_size == 16
    assert cfg.q_block_size == 32 and cfg.policy.q_block_size == 32
    assert cfg.interpret is True


def test_nsa_config_rejects_removed_spellings():
    """The PR-5 deprecation shims (kernel=/selected_impl=/paged_kernel=)
    are gone: the old kwargs now fail loudly instead of warning."""
    with pytest.raises(TypeError):
        NSAConfig(kernel="fsa")
    with pytest.raises(TypeError):
        NSAConfig(selected_impl="gather")
    with pytest.raises(TypeError):
        NSAConfig(paged_kernel=True)


def test_policy_is_algorithm_invariant():
    """Swapping the policy never changes the math (same output)."""
    p, gates, q, k, v = _nsa_state(2, seed=3)
    outs = []
    for pol in (KernelPolicy(backend="sparse_union"),
                KernelPolicy(backend="fsa", q_block_size=32)):
        cfg = dataclasses.replace(CFG, policy=pol)
        outs.append(nsa_attention(p, gates, q, k, v, cfg=cfg, mode="prefill"))
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               atol=3e-5, rtol=3e-5)


def test_legacy_impl_aliases_resolve():
    from repro.attention import normalize_backend_name
    assert normalize_backend_name("sparse", CFG) == "sparse_union"
    assert normalize_backend_name("kernel", CFG) == "fsa"
    cfg = dataclasses.replace(CFG, policy=KernelPolicy(backend="nsa"))
    assert normalize_backend_name("kernel", cfg) == "nsa"
    cfg = dataclasses.replace(CFG,
                              policy=KernelPolicy(backend="sparse_gather"))
    assert normalize_backend_name("sparse", cfg) == "sparse_gather"


def test_no_warnings_on_new_spellings():
    """Plain construction and the unified entry never warn."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cfg = NSAConfig(block_size=16, num_selected=4, cmp_block_size=8,
                        cmp_stride=4, window_size=32, q_block_size=32,
                        interpret=True, min_seq_for_sparse=1,
                        policy=KernelPolicy(backend="sparse_union"))
        p, gates, q, k, v = _nsa_state(2, seed=4)
        nsa_attention(p, gates, q, k, v, cfg=cfg, mode="prefill")
