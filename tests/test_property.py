"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import indexing, selection
from repro.core.nsa_config import NSAConfig
from repro.data.pipeline import pack_documents

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def selection_case(draw):
    b_k = draw(st.sampled_from([8, 16]))
    n_blocks = draw(st.integers(2, 8))
    n = b_k * n_blocks
    h_k = draw(st.integers(1, 3))
    # T must cover the forced blocks (init + current); NSA uses T >= 3
    t_sel = draw(st.integers(min(2, n_blocks), min(6, n_blocks)))
    seed = draw(st.integers(0, 2**16))
    cfg = NSAConfig(block_size=b_k, num_selected=t_sel, cmp_block_size=8,
                    cmp_stride=4, q_block_size=b_k, num_init_blocks=1,
                    num_local_blocks=1)
    scores = jax.random.uniform(jax.random.PRNGKey(seed), (n, h_k, n_blocks))
    return cfg, scores, n


@given(selection_case())
@settings(**SETTINGS)
def test_selection_invariants(case):
    cfg, scores, n = case
    idx, valid = selection.select_blocks(scores, jnp.arange(n), cfg, n)
    idx, valid = np.asarray(idx), np.asarray(valid)
    cur = np.arange(n) // cfg.block_size
    for t in range(n):
        for hk in range(idx.shape[1]):
            sel = idx[t, hk][valid[t, hk]]
            # causal: no selected block starts after the query token
            assert (sel <= cur[t]).all()
            # unique and ascending
            assert (np.diff(sel) > 0).all()
            # forced blocks present: initial block 0 and the current block
            assert 0 in sel
            assert cur[t] in sel


@given(selection_case())
@settings(**SETTINGS)
def test_union_index_builder_covers_selection(case):
    """Every (token, selected block) appears in its q-block's union list."""
    cfg, scores, n = case
    idx, valid = selection.select_blocks(scores, jnp.arange(n), cfg, n)
    kv_ids, kv_cnt = indexing.build_qblock_union(idx, valid, cfg, n)
    kv_ids, kv_cnt = np.asarray(kv_ids), np.asarray(kv_cnt)
    idx, valid = np.asarray(idx), np.asarray(valid)
    bq = cfg.q_block_size
    for t in range(n):
        qb = t // bq
        for hk in range(idx.shape[1]):
            union = set(kv_ids[hk, qb, :kv_cnt[hk, qb]].tolist())
            for blk in idx[t, hk][valid[t, hk]]:
                assert int(blk) in union


@given(selection_case())
@settings(**SETTINGS)
def test_kvlist_slot_mapping_consistent(case):
    """I_i/O_i duality: if q-block qb is listed for KV block i with slot s,
    then the union list of qb has block i at position s."""
    cfg, scores, n = case
    idx, valid = selection.select_blocks(scores, jnp.arange(n), cfg, n)
    kv_ids, kv_cnt = indexing.build_qblock_union(idx, valid, cfg, n)
    q_ids, slot_ids, q_cnt = indexing.build_kvblock_qlists(idx, valid, cfg, n)
    kv_ids, kv_cnt = np.asarray(kv_ids), np.asarray(kv_cnt)
    q_ids, slot_ids, q_cnt = (np.asarray(a) for a in (q_ids, slot_ids, q_cnt))
    h_k, b, _ = q_ids.shape
    for hk in range(h_k):
        for i in range(b):
            for j in range(q_cnt[hk, i]):
                qb, s = q_ids[hk, i, j], slot_ids[hk, i, j]
                assert s < kv_cnt[hk, qb]
                assert kv_ids[hk, qb, s] == i


@given(st.integers(0, 2**16), st.integers(1, 4))
@settings(**SETTINGS)
def test_online_softmax_block_permutation_invariance(seed, nblocks):
    """Processing KV blocks in any order gives the same online softmax."""
    key = jax.random.PRNGKey(seed)
    s = jax.random.normal(key, (4, nblocks * 8))
    blocks = jnp.split(s, nblocks, axis=1)

    def online(blocks):
        m = jnp.full((4, 1), -1e30)
        l = jnp.zeros((4, 1))
        acc = jnp.zeros((4, 1))
        for blk in blocks:
            m_new = jnp.maximum(m, blk.max(1, keepdims=True))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(blk - m_new)
            l = corr * l + p.sum(1, keepdims=True)
            acc = corr * acc + p.sum(1, keepdims=True)
            m = m_new
        return m + jnp.log(l)

    lse_fwd = online(blocks)
    lse_rev = online(blocks[::-1])
    np.testing.assert_allclose(lse_fwd, lse_rev, rtol=1e-6)
    full = jax.nn.logsumexp(s, axis=1, keepdims=True)
    np.testing.assert_allclose(lse_fwd, full, rtol=1e-5)


@given(st.lists(st.integers(1, 40), min_size=1, max_size=10),
       st.sampled_from([16, 32]))
@settings(**SETTINGS)
def test_pack_documents_roundtrip(doc_lens, seq_len):
    docs = [np.full(l, i + 1, np.int32) for i, l in enumerate(doc_lens)]
    rows, segs = pack_documents(docs, seq_len)
    assert rows.shape == segs.shape and rows.shape[1] == seq_len
    # total non-pad tokens preserved
    assert (segs > 0).sum() == sum(doc_lens)
    # each row's segments are non-decreasing (packing is contiguous)
    for r in segs:
        nz = r[r > 0]
        assert (np.diff(nz) >= 0).all()


@given(st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_gradient_compression_error_feedback(seed):
    """Error feedback: compressing the same gradient repeatedly converges to
    the true value (residual re-injects quantization error)."""
    from repro.optim.compression import compress, decompress

    g = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (64,)))
    res = jnp.zeros_like(g)
    acc = np.zeros_like(g)
    for step in range(20):
        q, scale, res = compress(jnp.asarray(g), res)
        acc += np.asarray(decompress(q, scale))
    np.testing.assert_allclose(acc / 20, g, atol=0.05)
