"""Telemetry overhead smoke check.

Runs the tiny serve_bench workload twice — global telemetry off (the
default: the engine still keeps its private always-on registry, that cost is
part of the product) and fully on (global registry + JSONL event sink) — and
fails when the telemetry-on decode throughput drops by more than
``--threshold`` (default 5%).  This is the guard for the subsystem's design
contract: near-zero cost when disabled, bounded cost when enabled.

Each arm takes the best of ``--reps`` runs, for the same reason
``kernel_bench.time_call`` takes min-of-reps: scheduler spikes on shared CI
runners hit single runs, not the per-run minimum.

  PYTHONPATH=src python benchmarks/telemetry_overhead.py --threshold 0.05
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

try:
    from benchmarks.serve_bench import TINY, run_workload
except ImportError:      # script-style run: benchmarks/ itself is sys.path[0]
    from serve_bench import TINY, run_workload
from repro import telemetry
from repro.configs import get_config, reduced


def _arm(cfg, *, reps: int, seed: int) -> float:
    best = 0.0
    for r in range(reps):
        out = run_workload(cfg, release_every=2, seed=seed + r, quiet=True,
                           **TINY)
        best = max(best, out["decode_tok_s"])
    return best


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--threshold", type=float, default=0.05,
                    help="max tolerated relative decode-throughput drop with "
                         "telemetry on (0.05 = 5%%)")
    ap.add_argument("--reps", type=int, default=2,
                    help="runs per arm (best-of)")
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch))
    # warm arm: first run pays jit compilation for both arms' measurements
    run_workload(cfg, release_every=2, seed=123, quiet=True, **TINY)

    telemetry.disable()
    off = _arm(cfg, reps=args.reps, seed=0)

    jsonl = tempfile.NamedTemporaryFile(suffix=".jsonl", delete=False)
    jsonl.close()
    telemetry.enable(jsonl=jsonl.name)
    try:
        on = _arm(cfg, reps=args.reps, seed=0)
    finally:
        telemetry.disable()
        os.unlink(jsonl.name)

    drop = 1 - on / off if off > 0 else 0.0
    print(f"[telemetry_overhead] decode tok/s: off={off:.1f} on={on:.1f} "
          f"(drop {drop:+.1%}, threshold {args.threshold:.0%})")
    if drop > args.threshold:
        print("[telemetry_overhead] FAIL: enabling telemetry costs more "
              "than the threshold")
        return 1
    print("[telemetry_overhead] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
