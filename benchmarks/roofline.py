"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape) on the single-pod 16×16 mesh:
  compute term    = HLO_FLOPs   / (chips × 197e12 FLOP/s)
  memory term     = HLO_bytes   / (chips × 819e9 B/s)
  collective term = coll_bytes  / (chips × 50e9 B/s per ICI link)
(all numerators are totals = per-device × chips, so terms reduce to the
per-device values over per-chip rates).  FLOPs/bytes/collectives come from
the trip-count-corrected HLO walk (launch/hlo_analysis.py), since XLA's
cost_analysis counts loop bodies once.

Also reports MODEL_FLOPS (6·N_active·tokens for training, 2·N_active·tokens
for prefill/decode) and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import json
import pathlib

import jax

V5E = {"flops": 197e12, "hbm": 819e9, "ici": 50e9}
DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

_SUGGEST = {
    "compute": "reduce recompute (remat policy) or shard more FLOPs onto idle axes",
    "memory": "fuse/bf16-ize intermediate traffic; shrink gathered-KV working set",
    "collective": "overlap TP collectives with compute; reduce-scatter instead of all-reduce; cast comms to bf16",
}


def count_params(arch: str):
    """(total, active) parameter counts — active scales routed experts."""
    from repro.configs import get_config
    from repro.models import build

    cfg = get_config(arch)
    model = build(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = active = 0
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        frac = 1.0
        if cfg.moe is not None and "/moe/w_" in "/" + path:
            frac = cfg.moe.top_k / cfg.moe.num_experts
        active += n * frac
    return total, active


def model_flops(arch: str, shape_rec: dict) -> float:
    from repro.configs import SHAPES

    shape = SHAPES[shape_rec["shape"]]
    _, active = count_params(arch)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch          # decode: one token/slot


def analyze_cell(rec: dict) -> dict | None:
    if "skipped" in rec or "error" in rec:
        return None
    n_dev = rec["devices"]
    terms = {
        "compute": rec["flops_per_device"] / V5E["flops"],
        "memory": rec["bytes_per_device"] / V5E["hbm"],
        "collective": rec["collective_bytes_per_device"].get("total", 0.0)
                      / V5E["ici"],
    }
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec)
    hlo_total = rec["flops_per_device"] * n_dev
    useful = mf / hlo_total if hlo_total else 0.0
    bound = max(terms.values())
    # roofline fraction: useful model flops per chip-second at the bound
    mfu_bound = (mf / n_dev / V5E["flops"]) / bound if bound else 0.0
    return {**{k: rec[k] for k in ("arch", "shape", "mesh", "mode")},
            "terms_s": {k: round(v, 6) for k, v in terms.items()},
            "dominant": dom,
            "model_flops": mf,
            "hlo_flops_total": hlo_total,
            "useful_ratio": round(useful, 4),
            "roofline_fraction": round(mfu_bound, 4),
            "suggestion": _SUGGEST[dom],
            "peak_bytes_per_dev": rec["memory"]["peak_bytes"],
            "temp_bytes_per_dev": rec["memory"]["temp_bytes"]}


def load_all(mesh: str = "16x16"):
    rows = []
    for p in sorted(DRYRUN.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("mesh") != mesh:
            continue
        row = analyze_cell(rec)
        if row:
            rows.append(row)
    return rows


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL/HLO | roofline frac |\n|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        t = r["terms_s"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute']:.4f} | "
            f"{t['memory']:.4f} | {t['collective']:.4f} | {r['dominant']} | "
            f"{r['useful_ratio']:.3f} | {r['roofline_fraction']:.3f} |")
    return hdr + "\n".join(lines) + "\n"


def main():
    rows = load_all()
    print("roofline,arch,shape,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,roofline_fraction")
    for r in rows:
        t = r["terms_s"]
        print(f"roofline,{r['arch']},{r['shape']},{t['compute']:.5f},"
              f"{t['memory']:.5f},{t['collective']:.5f},{r['dominant']},"
              f"{r['useful_ratio']:.4f},{r['roofline_fraction']:.4f}")
    out = DRYRUN.parent / "roofline.md"
    out.write_text(markdown_table(rows))
    print(f"roofline,table_written,{out}")


if __name__ == "__main__":
    main()
