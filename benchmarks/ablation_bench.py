"""FSA kernel ablations (paper Fig. 9 analogue).

Paper ablations: disabling the inner-loop optimization (−11.9% avg) and the
early-return design (−18.2% avg).  TPU twins of those knobs:

  * early-return OFF  — force every query block to walk the full union cap
    (kv_cnt := cap): measures the value of the count-bounded inner loop.
  * group folding OFF — process each of the g query heads in its own M-rows
    (M = B_Q instead of B_Q·g): measures the value of folding the GQA group
    into the matmul M dimension (the FSA idea itself, at block scale).

Reported as analytic memory-traffic deltas + CPU interpret-mode wall time
(directional), since no TPU is attached.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import NSAConfig, indexing
from repro.core.selection import select_blocks
from repro.kernels import fsa_selected, ref


def _t(fn, reps=3):
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def main():
    n, g, h_k, d, b_k, t_sel = 256, 2, 2, 32, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    h = g * h_k
    q = jax.random.normal(ks[0], (n, h, d))
    k = jax.random.normal(ks[1], (n, h_k, d))
    v = jax.random.normal(ks[2], (n, h_k, d))
    cfg = NSAConfig(block_size=b_k, num_selected=t_sel, q_block_size=32,
                    cmp_block_size=8, cmp_stride=4)
    scores = jax.random.uniform(ks[3], (n, h_k, n // b_k))
    idx, valid = select_blocks(scores, jnp.arange(n), cfg, n)

    sel = jnp.where(valid, idx, -1).astype(jnp.int32)
    sel_rows = jnp.repeat(sel.transpose(1, 0, 2), g, axis=1)
    q_rows = ref.rows_from_heads(q, h_k)
    k_t, v_t = k.transpose(1, 0, 2), v.transpose(1, 0, 2)
    kv_ids, kv_cnt = indexing.build_qblock_union(idx, valid, cfg, n)
    cap = kv_ids.shape[-1]

    base = jax.jit(lambda: fsa_selected.fsa_selected(
        q_rows, k_t, v_t, sel_rows, kv_ids, kv_cnt, g=g,
        block_q=cfg.q_block_size, block_k=b_k))
    # ablation 1: early return off (every block walks the full cap, masked)
    no_early = jax.jit(lambda: fsa_selected.fsa_selected(
        q_rows, k_t, v_t, sel_rows, kv_ids, kv_cnt, g=g,
        block_q=cfg.q_block_size, block_k=b_k, early_return=False))
    # ablation 2: group folding off (per-head calls, M = B_Q)
    def per_head():
        outs = []
        for gi in range(g):
            qh = q_rows.reshape(h_k, n, g, d)[:, :, gi]
            sh = sel_rows.reshape(h_k, n, g, -1)[:, :, gi]
            outs.append(fsa_selected.fsa_selected(
                qh, k_t, v_t, sh, kv_ids, kv_cnt, g=1,
                block_q=cfg.q_block_size, block_k=b_k))
        return jnp.stack(outs)
    no_fold = jax.jit(per_head)

    t_base = _t(base)
    t_noearly = _t(no_early)
    t_nofold = _t(no_fold)

    # analytic deltas
    steps_base = float(kv_cnt.sum())
    steps_noearly = float(jnp.full_like(kv_cnt, cap).sum())
    kv_bytes = 2 * b_k * d * 2  # K+V per block, bf16-equivalent
    print("ablation,variant,cpu_us,inner_steps,kv_traffic_rel")
    print(f"ablation,fsa_full,{t_base:.0f},{steps_base:.0f},1.00")
    print(f"ablation,no_early_return,{t_noearly:.0f},{steps_noearly:.0f},"
          f"{steps_noearly/steps_base:.2f}")
    print(f"ablation,no_group_fold,{t_nofold:.0f},{steps_base*g:.0f},"
          f"{g:.2f}")
    # correctness: ablations must not change results
    import numpy as np
    np.testing.assert_allclose(base(), no_early(), atol=1e-5)
    print("ablation,correctness,PASS,ablations bit-match the base kernel")


if __name__ == "__main__":
    main()
