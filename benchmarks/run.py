"""Benchmark harness entry: one section per paper table/figure.

Prints ``name,...`` CSV lines.  Sections:
  analytic_model  -- Fig. 2 (memory/FLOPs model; validates the paper's 21.3% /
                     56.2% numbers exactly)
  kernel_bench    -- Fig. 4 (CPU interpret timings + v5e roofline projection)
  e2e_bench       -- Fig. 5/6 (real reduced-model train/prefill wall time)
  breakdown       -- Fig. 7/8/11 (fwd/bwd + branch shares)
  ablation        -- Fig. 9 (early-return / group-fold ablations)
  roofline        -- Roofline terms from the dry-run artifacts (if present)
"""
from __future__ import annotations

import traceback


def _section(name, fn):
    print(f"# --- {name} ---")
    try:
        fn()
    except Exception as e:  # noqa: BLE001 -- benchmarks are independent
        print(f"{name},ERROR,{type(e).__name__}: {e}")
        traceback.print_exc()


def main() -> None:
    from benchmarks import (ablation_bench, analytic_model, breakdown_bench,
                            e2e_bench, kernel_bench, roofline)

    _section("analytic_model", analytic_model.main)
    _section("kernel_bench", kernel_bench.main)
    _section("e2e_bench", e2e_bench.main)
    _section("breakdown_bench", breakdown_bench.main)
    _section("ablation_bench", ablation_bench.main)
    _section("roofline", roofline.main)


if __name__ == '__main__':
    main()
