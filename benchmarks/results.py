"""Machine-readable benchmark results writer, shared by the bench CLIs and
the CI bench-smoke job.

Every benchmark that wants a perf-trajectory point calls ``write_results``
with a flat-ish payload dict; the file lands as ``BENCH_<name>.json`` with a
small envelope (bench name, schema version, environment fingerprint) so
points from different commits / jax versions remain comparable.
"""
from __future__ import annotations

import json
import platform
import sys
import time

SCHEMA_VERSION = 1


def environment() -> dict:
    """Versions that perf points must be keyed on to stay comparable."""
    import jax

    return {
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
    }


def write_results(path: str, name: str, payload: dict) -> dict:
    """Write one bench-trajectory point to ``path`` (JSON). Returns the doc."""
    doc = {
        "bench": name,
        "schema_version": SCHEMA_VERSION,
        "unix_time": time.time(),
        "environment": environment(),
        "results": payload,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True, default=float)
        f.write("\n")
    print(f"[{name}] wrote {path}", file=sys.stderr)
    return doc


def write_telemetry_snapshot(path: str, snapshot: dict, *,
                             source: str = "") -> dict:
    """Write a ``telemetry.Registry.snapshot()`` (or a dict of several, e.g.
    ``{"global": ..., "engine": ...}``) in the same envelope, under bench
    name ``telemetry_snapshot``.  Not a perf point — ``check_regression``
    does not gate on it; the trajectory tool reads the dispatch counters."""
    return write_results(path, "telemetry_snapshot",
                         {"source": source, "snapshot": snapshot})
