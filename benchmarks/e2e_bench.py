"""End-to-end train / prefill latency (paper Fig. 5 & 6 analogue).

Measures REAL wall time of the full train_step / prefill for a reduced-size
model on CPU, comparing NSA(FSA sparse path) vs full attention — the shape of
the paper's comparison at a scale this container can execute.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro.configs import get_config, reduced
from repro.launch.mesh import make_mesh, mesh_context
from repro.launch.steps import make_train_step
from repro.models import build
from repro.models.registry import make_reduced_batch
from repro.optim import AdamWConfig, init_opt_state


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def bench_arch(arch: str, seq: int = 256, batch: int = 2):
    rows = []
    mesh = make_mesh((1, 1), ("data", "model"))
    for attn, label in (("nsa", "fsa"), ("full", "full")):
        cfg = dataclasses.replace(reduced(get_config(arch)),
                                  attention=attn, n_layers=4)
        model = build(cfg)
        with mesh_context(mesh):
            params = model.init(jax.random.PRNGKey(0))
            batch_data = make_reduced_batch(cfg, jax.random.PRNGKey(1),
                                            batch, seq)
            state = {"params": params,
                     "opt": init_opt_state(params, AdamWConfig())}
            step = jax.jit(make_train_step(cfg, mesh, AdamWConfig()))
            us_train = _time(step, state, batch_data)
            # prefill = loss fwd only
            fwd = jax.jit(lambda p, b: model.loss(p, b)[0])
            us_prefill = _time(fwd, params, batch_data)
        rows.append((f"{arch}/{label}", us_train, us_prefill))
    return rows


def main():
    print("e2e_bench,config,train_us_per_step,prefill_us")
    for arch in ("codeqwen1.5-7b", "h2o-danube-3-4b", "olmoe-1b-7b"):
        for name, tr, pf in bench_arch(arch):
            print(f"e2e_bench,{name},{tr:.0f},{pf:.0f}")


if __name__ == "__main__":
    main()
