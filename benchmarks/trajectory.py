"""Collate per-commit ``BENCH_*.json`` points into one perf trajectory.

The CI bench-smoke job uploads a ``BENCH_kernel.json`` / ``BENCH_serve.json``
pair per run (the ``benchmarks/results.py`` envelope).  This tool takes any
number of such documents — downloaded artifacts from several commits, the
committed baselines, a fresh local run — and collates them into

  * ``BENCH_trajectory.json`` — per-bench, per-metric time series (sorted by
    the envelope's ``unix_time``), with the environment fingerprint of every
    point kept so cross-version segments remain identifiable;
  * a markdown table (``--md-out``) with first/last values and the relative
    drift, for dropping into a PR comment or the job summary.

Metric extraction is shared with ``check_regression.py`` (same names, same
microsecond normalization), so the trajectory shows exactly what the gate
gates — ``cpu_interpret_us/*`` forward latencies, ``bwd_ms/*`` training-step
latencies, serve latencies/throughputs.

Usage (the CI bench-smoke job collates the committed baseline with the fresh
run — a two-point trajectory per metric; longer histories come from feeding
more artifacts):

  python benchmarks/trajectory.py BENCH_kernel.json BENCH_serve.json \
      benchmarks/baselines/*.json \
      --json-out BENCH_trajectory.json --md-out BENCH_trajectory.md
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

try:
    from benchmarks.check_regression import extract
except ImportError:      # script-style run: benchmarks/ itself is sys.path[0]
    from check_regression import extract


def load_points(paths) -> list:
    """Read envelope documents, skipping files that are not bench points."""
    points = []
    for p in paths:
        path = pathlib.Path(p)
        try:
            doc = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"[trajectory] skip {path}: {e}", file=sys.stderr)
            continue
        if "bench" not in doc or "results" not in doc:
            print(f"[trajectory] skip {path}: not a bench envelope",
                  file=sys.stderr)
            continue
        points.append((doc, str(path)))
    return points


def collate(points) -> dict:
    """{bench: {"points": [...], "series": {metric: [values...]}}}.

    Points are sorted by ``unix_time`` within each bench; a metric absent
    from some point contributes ``None`` at that position, so gaps (a
    backend added later, a retired metric) stay visible instead of silently
    compacting the series."""
    by_bench = {}
    for doc, src in points:
        by_bench.setdefault(doc["bench"], []).append((doc, src))
    out = {}
    for bench, docs in by_bench.items():
        docs.sort(key=lambda d: d[0].get("unix_time", 0))
        metas, metrics_per_point = [], []
        for doc, src in docs:
            try:
                lat, thr = extract(doc)
            except SystemExit:
                # non-perf envelopes (e.g. telemetry snapshots swept up by a
                # BENCH_*.json glob) carry no gateable metrics — skip, don't die
                print(f"[trajectory] skip {src}: bench "
                      f"{doc.get('bench')!r} has no trajectory metrics",
                      file=sys.stderr)
                continue
            metrics_per_point.append({**lat, **thr})
            metas.append({
                "source": src,
                "unix_time": doc.get("unix_time"),
                "environment": doc.get("environment", {}),
            })
        if not metrics_per_point:      # every doc of this bench was skipped
            continue
        names = sorted(set().union(*metrics_per_point))
        series = {m: [pt.get(m) for pt in metrics_per_point] for m in names}
        out[bench] = {"points": metas, "series": series}
    return out


def _mermaid_chart(bench: str, metric: str, values: list) -> list:
    """One mermaid xychart-beta block (GitHub step summaries render these
    natively — a plot with zero plotting dependencies).  ``None`` gaps are
    carried forward so the line stays drawable."""
    pts, last = [], None
    for v in values:
        last = v if v is not None else last
        pts.append(last)
    pts = [p for p in pts if p is not None]
    if len(pts) < 2:
        return []
    return [
        "```mermaid",
        "xychart-beta",
        f'    title "{bench}: {metric}"',
        f'    x-axis "commit" [{", ".join(str(i + 1) for i in range(len(pts)))}]',
        f'    y-axis "{metric}"',
        f'    line [{", ".join(f"{p:.2f}" for p in pts)}]',
        "```",
        "",
    ]


def telemetry_tick_charts(jsonl_path, *, max_points: int = 60) -> list:
    """Markdown (mermaid xychart) queue-depth / active-slot series from the
    ``tick`` events of a telemetry JSONL stream (``serve_bench
    --telemetry-jsonl``).  Long runs are downsampled to ``max_points``."""
    ticks = []
    try:
        with open(jsonl_path) as f:
            for line in f:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if rec.get("kind") == "tick":
                    ticks.append(rec)
    except OSError as e:
        print(f"[trajectory] skip telemetry jsonl {jsonl_path}: {e}",
              file=sys.stderr)
        return []
    if len(ticks) < 2:
        return []
    step = max(1, len(ticks) // max_points)
    ticks = ticks[::step]
    lines = [f"## serving timeline ({jsonl_path}, {len(ticks)} ticks"
             + (f", 1/{step} sampled" if step > 1 else "") + ")", ""]
    for metric in ("queue_depth", "active_slots", "page_util_raw"):
        vals = [float(t.get(metric, 0) or 0) for t in ticks]
        if not any(vals):
            continue
        lines += [
            "```mermaid",
            "xychart-beta",
            f'    title "{metric} per tick"',
            f'    x-axis "tick" [{", ".join(str(t.get("tick", i + 1)) for i, t in enumerate(ticks))}]',
            f'    y-axis "{metric}"',
            f'    line [{", ".join(f"{v:.2f}" for v in vals)}]',
            "```",
            "",
        ]
    return lines


def telemetry_dispatch_md(snapshot_doc: dict) -> list:
    """Markdown dispatch-mix table from a telemetry-snapshot envelope (the
    ``attention_dispatch_total`` counters of the global registry)."""
    snaps = snapshot_doc.get("results", {}).get("snapshot", {})
    if "counters" in snaps:            # bare snapshot, not {"global": ...}
        snaps = {"": snaps}
    rows = []
    for reg_name, snap in sorted(snaps.items()):
        counters = (snap or {}).get("counters", {})
        for name in ("attention_dispatch_total",
                     "attention_resolve_fallback_total"):
            for labelkey, value in sorted(counters.get(name, {}).items()):
                rows.append((reg_name, name, labelkey, value))
    if not rows:
        return []
    lines = ["## attention dispatch mix", "",
             "| registry | counter | labels | count |",
             "|---|---|---|---:|"]
    for reg_name, name, labelkey, value in rows:
        lines.append(f"| {reg_name or 'global'} | {name} "
                     f"| `{labelkey or '-'}` | {int(value)} |")
    lines.append("")
    return lines


def markdown(traj: dict, *, plot: bool = False, plot_limit: int = 6) -> str:
    lines = ["# Bench trajectory", ""]
    for bench, data in sorted(traj.items()):
        n = len(data["points"])
        lines += [f"## {bench} ({n} point{'s' * (n != 1)})", "",
                  "| metric | first | last | drift |",
                  "|---|---:|---:|---:|"]
        drifts = {}
        for metric, values in sorted(data["series"].items()):
            present = [v for v in values if v is not None]
            if not present:
                continue
            first, last = present[0], present[-1]
            drift = f"{(last / first - 1):+.1%}" if first else "n/a"
            gap = "" if len(present) == len(values) else " (gaps)"
            lines.append(f"| {metric} | {first:.1f} | {last:.1f} "
                         f"| {drift}{gap} |")
            if first:
                drifts[metric] = abs(last / first - 1)
        lines.append("")
        if plot and n >= 2:
            # chart the most-drifted metrics — the ones worth eyeballing
            top = sorted(drifts, key=drifts.get, reverse=True)[:plot_limit]
            for metric in top:
                lines += _mermaid_chart(bench, metric, data["series"][metric])
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("inputs", nargs="+",
                    help="BENCH_*.json envelope documents (any benches, any "
                         "number of commits; grouped and time-sorted here)")
    ap.add_argument("--json-out", default="BENCH_trajectory.json")
    ap.add_argument("--md-out", default=None,
                    help="also write the markdown drift table here")
    ap.add_argument("--plot", action="store_true",
                    help="append mermaid xychart blocks (rendered natively "
                         "by GitHub step summaries) for the most-drifted "
                         "metrics of each bench")
    ap.add_argument("--telemetry-jsonl", default=None,
                    help="telemetry event stream (serve_bench "
                         "--telemetry-jsonl): append per-tick queue-depth / "
                         "slot-occupancy charts to the markdown")
    ap.add_argument("--telemetry-snapshot", default=None,
                    help="telemetry-snapshot envelope: append the "
                         "backend-dispatch-mix table to the markdown")
    args = ap.parse_args(argv)

    points = load_points(args.inputs)
    if not points:
        print("[trajectory] no valid bench documents given", file=sys.stderr)
        return 1
    traj = collate(points)
    pathlib.Path(args.json_out).write_text(
        json.dumps(traj, indent=2, sort_keys=True, default=float) + "\n")
    print(f"[trajectory] wrote {args.json_out} "
          f"({sum(len(d['points']) for d in traj.values())} points, "
          f"{len(traj)} benches)", file=sys.stderr)
    md = markdown(traj, plot=args.plot)
    extra = []
    if args.telemetry_jsonl:
        extra += telemetry_tick_charts(args.telemetry_jsonl)
    if args.telemetry_snapshot:
        try:
            snap_doc = json.loads(
                pathlib.Path(args.telemetry_snapshot).read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"[trajectory] skip telemetry snapshot: {e}",
                  file=sys.stderr)
        else:
            extra += telemetry_dispatch_md(snap_doc)
    if extra:
        md += "\n".join(extra) + "\n"
    if args.md_out:
        pathlib.Path(args.md_out).write_text(md)
        print(f"[trajectory] wrote {args.md_out}", file=sys.stderr)
    else:
        print(md)
    return 0


if __name__ == "__main__":
    sys.exit(main())
