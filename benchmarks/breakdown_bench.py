"""Breakdown benchmarks (paper Fig. 7, 8, 11 analogues).

Fig. 7: forward vs backward attention latency (FSA vs NSA-ref vs full).
Fig. 8: per-branch share (selected / compressed / sliding) — validates the
        paper's claim that selected attention dominates (65–79%).
Fig. 11: attention vs MLP share of a full training step.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import attention as uattn
from repro.core import (NSAConfig, apply_gates, compressed_and_selection,
                        init_nsa_params)
from repro.core import sparse
from repro.kernels import ops, ref


def _t(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def setup(n=512, g=2, h_k=2, d=32):
    cfg = NSAConfig(block_size=32, num_selected=8, cmp_block_size=16,
                    cmp_stride=8, window_size=64, q_block_size=64,
                    min_seq_for_sparse=1)
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    h = g * h_k
    p = init_nsa_params(ks[0], 64, h, d, cfg)
    x = jax.random.normal(ks[1], (n, 64))
    q = jax.random.normal(ks[2], (n, h, d))
    k = jax.random.normal(ks[3], (n, h_k, d))
    v = jax.random.normal(ks[4], (n, h_k, d))
    return cfg, p, apply_gates(p, x), q, k, v


def fwd_bwd_breakdown():
    cfg, p, gates, q, k, v = setup()
    _, idx, valid = compressed_and_selection(p, q, k, v, cfg, q_chunk=128)
    rows = []
    for kern in ("fsa", "nsa"):
        f = jax.jit(lambda q, k, v, kn=kern: uattn.selected_attention(
            q, k, v, idx, valid, cfg, kernel=kn).sum())
        g_ = jax.jit(jax.grad(lambda q, k, v, kn=kern: uattn.selected_attention(
            q, k, v, idx, valid, cfg, kernel=kn).sum(), argnums=(0, 1, 2)))
        rows.append((f"selected/{kern}", _t(f, q, k, v), _t(g_, q, k, v)))
    f = jax.jit(lambda q, k, v: ops.full_attention(q, k, v, cfg).sum())
    g_ = jax.jit(jax.grad(lambda q, k, v: ops.full_attention(
        q, k, v, cfg).sum(), argnums=(0, 1, 2)))
    rows.append(("full/flash", _t(f, q, k, v), _t(g_, q, k, v)))
    return rows


def branch_breakdown():
    """Per-branch cost inside the sparse NSA path (paper Fig. 8)."""
    cfg, p, gates, q, k, v = setup()
    from repro.core import compression
    from repro.core.reference import _gqa_out, _gqa_scores, _safe_softmax

    _, idx, valid = compressed_and_selection(p, q, k, v, cfg, q_chunk=128)
    n = q.shape[0]

    def cmp_branch(q, k, v):
        k_cmp, v_cmp = compression.compress_kv(p, k, v, cfg)
        vis = compression.cmp_visibility(jnp.arange(n), k_cmp.shape[0], cfg)
        probs, _ = _safe_softmax(_gqa_scores(q, k_cmp), vis[:, None, :])
        return _gqa_out(probs, v_cmp).sum()

    def sel_branch(q, k, v):
        return sparse.selected_gather_attention(
            q, k, v, idx, valid, cfg, jnp.arange(n)).sum()

    def win_branch(q, k, v):
        return ref.flash_ref_chunked(q, k, v, window=cfg.window_size,
                                     q_chunk=128).sum()

    rows = []
    for name, fn in (("compressed", cmp_branch), ("selected", sel_branch),
                     ("sliding", win_branch)):
        f = jax.jit(fn)
        gr = jax.jit(jax.grad(fn, argnums=(0, 1, 2)))
        rows.append((name, _t(f, q, k, v), _t(gr, q, k, v)))
    total_f = sum(r[1] for r in rows)
    total_b = sum(r[2] for r in rows)
    return rows, total_f, total_b


def main():
    print("breakdown,phase,fwd_us,bwd_us")
    for name, f, b in fwd_bwd_breakdown():
        print(f"breakdown,{name},{f:.0f},{b:.0f}")
    rows, tf, tb = branch_breakdown()
    for name, f, b in rows:
        print(f"breakdown,branch/{name},{f:.0f},{b:.0f},"
              f"share_fwd={f/tf:.2f}")
    sel = next(r for r in rows if r[0] == "selected")
    print(f"breakdown,selected_share,{sel[1]/tf:.2f},{sel[2]/tb:.2f},"
          f"paper_range=0.65-0.79")


if __name__ == "__main__":
    main()
