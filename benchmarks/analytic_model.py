"""Paper §3.3 analytic model — exact reproduction of Figure 2.

FSA:  memory = d·N·(6h + 2h_K)·(1+T) bytes (2B/elem folded into constants
      per the paper's convention);  FLOPs = d·N·B_K·T·(4h + 2h_K)
NSA:  memory = 2·d·h_K·N·(B_K·T + g + 8);   FLOPs = 32·d·h_K·N·B_K·T

Validation targets from the paper (g=4, B_K=64, T=16):
  memory ratio FSA/NSA = 21.3%,  FLOPs ratio = 56.2%.
"""
from __future__ import annotations


def fsa_memory_bytes(d, n, h, h_k, t):
    return d * n * (6 * h + 2 * h_k) * (1 + t)


def fsa_flops(d, n, h, h_k, b_k, t):
    return d * n * b_k * t * (4 * h + 2 * h_k)


def nsa_memory_bytes(d, n, h, h_k, b_k, t):
    g = h // h_k
    return 2 * d * h_k * n * (b_k * t + g + 8)


def nsa_flops(d, n, h, h_k, b_k, t):
    return 32 * d * h_k * n * b_k * t


def ratios(g, b_k, t, d=128, n=65536, h_k=4):
    h = g * h_k
    mem = fsa_memory_bytes(d, n, h, h_k, t) / nsa_memory_bytes(d, n, h, h_k, b_k, t)
    fl = fsa_flops(d, n, h, h_k, b_k, t) / nsa_flops(d, n, h, h_k, b_k, t)
    return mem, fl


def figure2_table():
    rows = []
    for b_k, t in ((64, 16), (128, 8)):
        for g in (1, 2, 4, 8, 16):
            mem, fl = ratios(g, b_k, t)
            rows.append({"B_K": b_k, "T": t, "g": g,
                         "mem_ratio": mem, "flops_ratio": fl})
    return rows


def validate_paper_claims():
    """Returns (ok, details) — the faithful-reproduction gate."""
    mem, fl = ratios(g=4, b_k=64, t=16)
    ok = abs(mem - 0.213) < 0.002 and abs(fl - 0.562) < 0.002
    return ok, {"mem_ratio@g4": round(mem, 4), "flops_ratio@g4": round(fl, 4),
                "paper": {"mem": 0.213, "flops": 0.562}}


def main():
    ok, det = validate_paper_claims()
    print(f"analytic_model,paper_validation,{'PASS' if ok else 'FAIL'},{det}")
    print("B_K,T,g,mem_ratio_fsa_over_nsa,flops_ratio_fsa_over_nsa")
    for r in figure2_table():
        print(f"{r['B_K']},{r['T']},{r['g']},{r['mem_ratio']:.4f},"
              f"{r['flops_ratio']:.4f}")


if __name__ == "__main__":
    main()
