"""Serving benchmark: mixed-length traffic through the paged NSA engine.

A Poisson-ish open-loop workload: prompts with lengths drawn from a range
are released over engine ticks (admission over time, not one up-front
batch), exercising the fused mixed tick (chunked prefill co-scheduled with
decode), per-slot positions, slot recycling and page reclamation.  Reports
tokens/sec (decode + prefill), per-request TTFT / end-to-end latency
percentiles (p50/p95) from the corrected per-request timestamps, and
raw + compressed page-pool utilization.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py --arch codeqwen1.5-7b

``--mesh DxM`` runs the same workload through the mesh-sharded engine
(``repro.serving.sharded``): KV-head-sharded page pools over the model
axis, slot-sharded engine replicas over data.  ``--metrics-port`` serves
the engine telemetry registry as a Prometheus ``/metrics`` endpoint for
the duration of the run.

``--json-out PATH`` writes a BENCH_serve.json trajectory point (shared
writer in ``benchmarks/results.py``) — the CI bench-smoke job uploads it as
a workflow artifact.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

try:
    from benchmarks.results import write_results, write_telemetry_snapshot
except ImportError:      # script-style run: benchmarks/ itself is sys.path[0]
    from results import write_results, write_telemetry_snapshot
from repro import telemetry
from repro.attention import AttentionRequest, resolve
from repro.configs import get_config, reduced
from repro.serving import Engine

# the CI bench-smoke workload (also --tiny): small enough for interpret-mode
# CPU, still exercising admission over time, chunked prefill and recycling
TINY = dict(slots=2, n_requests=3, min_prompt=8, max_prompt=24, new_tokens=4)


def _pctl(values, q):
    return float(np.percentile(values, q)) if values else 0.0


def run_workload(cfg, *, slots, n_requests, min_prompt, max_prompt, new_tokens,
                 release_every, prefill_chunk=None, seed=0, quiet=False,
                 backend=None, fused=True, prefill_token_budget=None,
                 prefix_cache=False, prompts=None, warmup_prompts=None,
                 burst=False, mesh=None, metrics_port=None,
                 engine_out: dict | None = None):
    """Release requests gradually; drive the engine until drained.

    Pass ``engine_out={}`` to receive the drained ``Engine`` under the
    ``"engine"`` key (its telemetry snapshot / timelines outlive the run).

    ``prompts`` overrides the random workload with explicit token arrays
    (the shared-prefix scenario runs the SAME prompts with the prefix cache
    on and off and compares).  ``warmup_prompts`` are served to completion
    before the measured workload (e.g. to materialize a common prefix in
    the cache); ``burst=True`` submits all measured prompts up front so
    they run concurrently instead of trickling in over ticks.
    """
    eng = Engine(cfg, n_slots=slots, max_len=max_prompt + new_tokens + 8,
                 prefill_chunk=prefill_chunk, backend=backend, fused=fused,
                 prefill_token_budget=prefill_token_budget,
                 prefix_cache=prefix_cache, mesh=mesh,
                 metrics_port=metrics_port)
    if engine_out is not None:
        engine_out["engine"] = eng
    if eng.metrics_server is not None and not quiet:
        print(f"[serve_bench] metrics at {eng.metrics_server.url}")
    rng = np.random.default_rng(seed)
    if prompts is None:
        pending = [rng.integers(0, cfg.vocab, size=(int(rng.integers(
            min_prompt, max_prompt + 1)),)) for _ in range(n_requests)]
    else:
        pending = [np.asarray(p, np.int32) for p in prompts]
    if warmup_prompts:
        for p in warmup_prompts:
            eng.submit(np.asarray(p, np.int32), max_new=1)
        while not eng.scheduler.idle():
            eng.step()

    reqs, tick = [], 0
    t0 = time.time()
    while pending or not eng.scheduler.idle():
        if pending and (burst or tick % release_every == 0):
            n = len(pending) if burst else 1        # one release per interval
            for _ in range(n):
                reqs.append(eng.submit(pending.pop(0), max_new=new_tokens))
        eng.step()
        tick += 1
    wall = time.time() - t0

    s = eng.summary()
    # per-request latencies from the corrected timestamps: first_token_t is
    # stamped per request AFTER its first token is on host, never one shared
    # pre-sync stamp for an admission batch (measured requests only — the
    # warmup pass is excluded)
    lat = [r.finish_t - r.submit_t for r in reqs if r.done]
    ttft = [r.first_token_t - r.submit_t for r in reqs
            if r.done and r.first_token_t]
    out = {
        "requests": len(reqs),
        # prompt_len (not len(r.prompt)): survives bounded-retention eviction
        "prompt_lens": [r.prompt_len for r in reqs],
        "decode_backend": resolve(
            eng.cfg.nsa, AttentionRequest(mode="paged_decode", paged=True)).name,
        "fused": fused,
        "mesh": ("x".join(str(s) for s in mesh.devices.shape)
                 if mesh is not None else None),
        "mixed_ticks": s["mixed_ticks"],
        "wall_s": wall,
        "decode_tok_s": s["decode_tokens_per_s"],
        "prefill_tok_s": s["prefill_tokens_per_s"],
        "decode_ms_tick": s["decode_ms_per_tick"],
        "peak_page_util": s["peak_page_util"],
        "peak_cmp_page_util": s["peak_cmp_page_util"],
        "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
        "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
        "ttft_p50_s": _pctl(ttft, 50),
        "ttft_p95_s": _pctl(ttft, 95),
        "e2e_p50_s": _pctl(lat, 50),
        "e2e_p95_s": _pctl(lat, 95),
        "total_new_tokens": s["decoded_tokens"] + len(reqs),
        "prefix_hit_rate": s["prefix_hit_rate"],
        "prefix_blocks_reused": s["prefix_blocks_reused"],
    }
    if prompts is not None:      # parity scenarios compare exact tokens
        out["outputs"] = [list(r.out) for r in reqs]
    if not quiet:
        print(f"[serve_bench] {len(reqs)} reqs, prompts "
              f"{min(out['prompt_lens'])}..{max(out['prompt_lens'])}, "
              f"slots={slots}, wall {wall:.2f}s"
              f" ({'fused' if fused else 'sequential'} ticks,"
              f" {s['mixed_ticks']} mixed)")
        print(f"  decode   {out['decode_tok_s']:8.1f} tok/s  "
              f"({out['decode_ms_tick']:.1f} ms/batched-tick)")
        print(f"  prefill  {out['prefill_tok_s']:8.1f} tok/s")
        print(f"  ttft     {out['ttft_p50_s']*1e3:8.1f} ms p50  "
              f"{out['ttft_p95_s']*1e3:8.1f} ms p95  "
              f"(mean {out['mean_ttft_s']*1e3:.1f} ms)")
        print(f"  e2e      {out['e2e_p50_s']*1e3:8.1f} ms p50  "
              f"{out['e2e_p95_s']*1e3:8.1f} ms p95  "
              f"(mean {out['mean_latency_s']*1e3:.1f} ms)")
        print(f"  pages    {out['peak_page_util']:8.1%} raw / "
              f"{out['peak_cmp_page_util']:.1%} cmp peak pool utilization")
    return out


def run_shared_prefix(cfg, frac, *, slots, n_requests, min_prompt, max_prompt,
                      new_tokens, release_every, seed=0, quiet=False,
                      backend=None, fused=True, prefill_token_budget=None,
                      mesh=None, metrics_port=None,
                      engine_out: dict | None = None):
    """A/B the prefix cache on a shared-prompt burst.

    ``frac * max_prompt`` leading tokens are common to every prompt (plus a
    private suffix of at least one token).  A warmup request materializes
    the shared prefix, then all measured requests are submitted at once —
    twice, with the prefix cache on and off — and the runs must produce
    EXACTLY the same tokens.  Reports the shared run's metrics plus the
    unshared peak raw-page utilization and the saving ratio.
    """
    rng = np.random.default_rng(seed)
    shared_len = max(int(frac * max_prompt), 1)
    lo = min(max(min_prompt, shared_len + 1), max_prompt)
    shared = rng.integers(0, cfg.vocab, size=(shared_len,))
    prompts = [np.concatenate([shared, rng.integers(0, cfg.vocab, size=(
        int(rng.integers(lo, max_prompt + 1)) - shared_len,))])
        for _ in range(n_requests)]
    warmup = np.concatenate([shared, rng.integers(0, cfg.vocab, size=(1,))])
    common = dict(slots=slots, n_requests=n_requests, min_prompt=lo,
                  max_prompt=max_prompt, new_tokens=new_tokens,
                  release_every=release_every, seed=seed, quiet=True,
                  backend=backend, fused=fused,
                  prefill_token_budget=prefill_token_budget, mesh=mesh,
                  prompts=prompts, warmup_prompts=[warmup], burst=True)
    # metrics_port only on the measured run — a fixed port can't bind twice
    on = run_workload(cfg, prefix_cache=True, engine_out=engine_out,
                      metrics_port=metrics_port, **common)
    off = run_workload(cfg, prefix_cache=False, **common)
    if on["outputs"] != off["outputs"]:
        raise AssertionError(
            "prefix cache changed tokens: shared run must be bit-identical "
            "to the unshared run")
    out = dict(on, shared_prefix_frac=frac,
               peak_page_util_unshared=off["peak_page_util"],
               page_saving_ratio=(off["peak_page_util"]
                                  / max(on["peak_page_util"], 1e-9)),
               token_parity=True)
    if not quiet:
        print(f"[serve_bench] shared-prefix {frac:.0%}: {n_requests} reqs, "
              f"{shared_len} common tokens, exact token parity OK")
        print(f"  pages    {out['peak_page_util']:8.1%} shared vs "
              f"{out['peak_page_util_unshared']:.1%} unshared peak raw "
              f"({out['page_saving_ratio']:.2f}x saving)")
        print(f"  prefix   {out['prefix_hit_rate']:8.1%} hit rate, "
              f"{out['prefix_blocks_reused']} blocks reused")
        print(f"  decode   {out['decode_tok_s']:8.1f} tok/s   "
              f"prefill {out['prefill_tok_s']:.1f} tok/s")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--min-prompt", type=int, default=16)
    ap.add_argument("--max-prompt", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--release-every", type=int, default=2,
                    help="engine ticks between request releases")
    ap.add_argument("--full-size", action="store_true",
                    help="run the full-size config (default: reduced CPU)")
    ap.add_argument("--backend", default=None,
                    help="paged-decode backend (registry name, e.g. "
                         "paged_kernel | paged_gather); default: cfg policy")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="shard the engine over a (data, model) mesh, e.g. "
                         "2x4 (needs data*model devices; model must divide "
                         "n_kv_heads, data must divide --slots)")
    ap.add_argument("--heads", type=int, default=None,
                    help="override n_heads (reduced runs; e.g. so the mesh "
                         "model axis divides the head counts)")
    ap.add_argument("--kv-heads", type=int, default=None,
                    help="override n_kv_heads (reduced runs)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the engine telemetry registry at "
                         "http://127.0.0.1:PORT/metrics for the run "
                         "(0 = ephemeral port, printed at startup)")
    ap.add_argument("--no-kernel", action="store_true",
                    help="decode via the gather reference instead of the "
                         "Pallas paged-decode kernel (alias for "
                         "--backend paged_gather)")
    ap.add_argument("--sequential", action="store_true",
                    help="run the legacy two-phase engine (full prefill, "
                         "then decode) instead of the fused mixed tick")
    ap.add_argument("--prefill-token-budget", type=int, default=None,
                    help="cap on prefill chunk tokens per fused tick "
                         "(admission throttles to bound decode latency)")
    ap.add_argument("--shared-prefix", type=float, default=0.0,
                    metavar="FRAC",
                    help="shared-prompt scenario: FRAC of max-prompt tokens "
                         "common to every request; A/Bs the prefix cache "
                         "against an unshared run (exact token parity "
                         "enforced) and reports the page-saving ratio")
    ap.add_argument("--json-out", default=None,
                    help="write a BENCH_serve.json trajectory point here")
    ap.add_argument("--tiny", action="store_true",
                    help="CI bench-smoke workload (slots/requests/prompt "
                         "sizes from serve_bench.TINY; explicit size flags "
                         "still override)")
    ap.add_argument("--telemetry", action="store_true",
                    help="enable global telemetry (dispatch counters, span "
                         "events) for this run")
    ap.add_argument("--telemetry-jsonl", default=None,
                    help="stream telemetry events (spans, engine ticks, "
                         "request timelines) to this JSONL file; implies "
                         "--telemetry")
    ap.add_argument("--telemetry-snapshot", default=None,
                    help="write the final global+engine telemetry snapshot "
                         "here (results.py envelope); implies --telemetry")
    args = ap.parse_args()

    if args.tiny:
        defaults = dict(slots=TINY["slots"], requests=TINY["n_requests"],
                        min_prompt=TINY["min_prompt"],
                        max_prompt=TINY["max_prompt"],
                        new_tokens=TINY["new_tokens"])
        for k, v in defaults.items():
            if getattr(args, k) == ap.get_default(k):
                setattr(args, k, v)
    if args.telemetry or args.telemetry_jsonl or args.telemetry_snapshot:
        telemetry.enable(jsonl=args.telemetry_jsonl)

    cfg = get_config(args.arch)
    head_overrides = {k: v for k, v in
                      (("n_heads", args.heads), ("n_kv_heads", args.kv_heads))
                      if v is not None}
    if not args.full_size:
        cfg = reduced(cfg, **head_overrides)
    elif head_overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **head_overrides)
    mesh = None
    if args.mesh is not None:
        from repro.launch.mesh import make_mesh
        d, m = (int(x) for x in args.mesh.lower().split("x"))
        mesh = make_mesh((d, m), ("data", "model"))
    engines: dict = {}
    common = dict(slots=args.slots, n_requests=args.requests,
                  min_prompt=args.min_prompt, max_prompt=args.max_prompt,
                  new_tokens=args.new_tokens,
                  release_every=args.release_every,
                  backend="paged_gather" if args.no_kernel else args.backend,
                  fused=not args.sequential,
                  prefill_token_budget=args.prefill_token_budget,
                  mesh=mesh, metrics_port=args.metrics_port,
                  engine_out=engines)
    if args.shared_prefix > 0:
        out = run_shared_prefix(cfg, args.shared_prefix, **common)
    else:
        out = run_workload(cfg, **common)
    if args.json_out:
        write_results(args.json_out, "serve_bench",
                      dict(out, arch=args.arch, full_size=args.full_size))
    if args.telemetry_snapshot:
        write_telemetry_snapshot(
            args.telemetry_snapshot,
            {"global": telemetry.registry().snapshot(),
             "engine": engines["engine"].telemetry.snapshot()},
            source="serve_bench")


if __name__ == "__main__":
    main()
