"""Serving benchmark: mixed-length traffic through the paged NSA engine.

A Poisson-ish open-loop workload: prompts with lengths drawn from a range
are released over engine ticks (admission over time, not one up-front
batch), exercising chunked prefill, per-slot positions, slot recycling and
page reclamation.  Reports tokens/sec (decode + prefill), latency, and
page-pool utilization.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py --arch codeqwen1.5-7b

``--json-out PATH`` writes a BENCH_serve.json trajectory point (shared
writer in ``benchmarks/results.py``) — the CI bench-smoke job uploads it as
a workflow artifact.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

try:
    from benchmarks.results import write_results
except ImportError:      # script-style run: benchmarks/ itself is sys.path[0]
    from results import write_results
from repro.attention import AttentionRequest, resolve
from repro.configs import get_config, reduced
from repro.serving import Engine


def run_workload(cfg, *, slots, n_requests, min_prompt, max_prompt, new_tokens,
                 release_every, prefill_chunk=None, seed=0, quiet=False,
                 backend=None):
    """Release requests gradually; drive the engine until drained."""
    eng = Engine(cfg, n_slots=slots, max_len=max_prompt + new_tokens + 8,
                 prefill_chunk=prefill_chunk, backend=backend)
    rng = np.random.default_rng(seed)
    pending = [rng.integers(0, cfg.vocab, size=(int(rng.integers(
        min_prompt, max_prompt + 1)),)) for _ in range(n_requests)]

    reqs, tick = [], 0
    t0 = time.time()
    while pending or not eng.scheduler.idle():
        if pending and tick % release_every == 0:   # one release per interval
            reqs.append(eng.submit(pending.pop(0), max_new=new_tokens))
        eng.step()
        tick += 1
    wall = time.time() - t0

    s = eng.summary()
    lat = [r.finish_t - r.submit_t for r in eng.scheduler.finished]
    ttft = [r.first_token_t - r.submit_t for r in eng.scheduler.finished
            if r.first_token_t]
    out = {
        "requests": len(reqs),
        "prompt_lens": [len(r.prompt) for r in reqs],
        "decode_backend": resolve(
            eng.cfg.nsa, AttentionRequest(mode="paged_decode", paged=True)).name,
        "wall_s": wall,
        "decode_tok_s": s["decode_tokens_per_s"],
        "prefill_tok_s": s["prefill_tokens_per_s"],
        "decode_ms_tick": s["decode_ms_per_tick"],
        "peak_page_util": s["peak_page_util"],
        "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
        "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
        "total_new_tokens": s["decoded_tokens"] + len(reqs),
    }
    if not quiet:
        print(f"[serve_bench] {len(reqs)} reqs, prompts "
              f"{min(out['prompt_lens'])}..{max(out['prompt_lens'])}, "
              f"slots={slots}, wall {wall:.2f}s")
        print(f"  decode   {out['decode_tok_s']:8.1f} tok/s  "
              f"({out['decode_ms_tick']:.1f} ms/batched-tick)")
        print(f"  prefill  {out['prefill_tok_s']:8.1f} tok/s")
        print(f"  latency  {out['mean_latency_s']*1e3:8.1f} ms mean  "
              f"(ttft {out['mean_ttft_s']*1e3:.1f} ms)")
        print(f"  pages    {out['peak_page_util']:8.1%} peak pool utilization")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--min-prompt", type=int, default=16)
    ap.add_argument("--max-prompt", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--release-every", type=int, default=2,
                    help="engine ticks between request releases")
    ap.add_argument("--full-size", action="store_true",
                    help="run the full-size config (default: reduced CPU)")
    ap.add_argument("--backend", default=None,
                    help="paged-decode backend (registry name, e.g. "
                         "paged_kernel | paged_gather); default: cfg policy")
    ap.add_argument("--no-kernel", action="store_true",
                    help="decode via the gather reference instead of the "
                         "Pallas paged-decode kernel (alias for "
                         "--backend paged_gather)")
    ap.add_argument("--json-out", default=None,
                    help="write a BENCH_serve.json trajectory point here")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    out = run_workload(cfg, slots=args.slots, n_requests=args.requests,
                       min_prompt=args.min_prompt, max_prompt=args.max_prompt,
                       new_tokens=args.new_tokens,
                       release_every=args.release_every,
                       backend="paged_gather" if args.no_kernel
                       else args.backend)
    if args.json_out:
        write_results(args.json_out, "serve_bench",
                      dict(out, arch=args.arch, full_size=args.full_size))


if __name__ == "__main__":
    main()
