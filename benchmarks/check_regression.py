"""Perf-trajectory regression gate.

Diffs a freshly produced ``BENCH_kernel.json`` / ``BENCH_serve.json``
(written by ``kernel_bench.py --json-out`` / ``serve_bench.py --json-out``
through the shared ``benchmarks/results.py`` envelope) against the committed
baselines in ``benchmarks/baselines/`` and exits non-zero when any latency
metric regressed by more than ``--threshold`` (default 20%).

Per-backend kernel latencies are compared key-by-key (``prefill/fsa``,
``paged_decode/paged_kernel``, ...), so a regression in ONE backend is
named, not averaged away.  Every dict-valued section of the kernel document
whose name carries a unit suffix (``*_us``, ``*_ms``, ``*_s``) is gated —
``cpu_interpret_us`` (forward) and ``bwd_ms`` (jax.grad training step) today,
any future section with zero gate changes.  Earlier versions hard-coded the
one forward section, so a baseline that carried additional sections the
candidate run omitted passed silently; now every baseline key in a unit
section must reappear in the candidate (or the gate fails as MISSING).
Metrics below ``--floor-us`` are skipped — micro-second-scale interpret-mode
numbers on shared CI runners are noise.  Throughput metrics (tok/s) regress
when they *drop* by the threshold.

Usage (the CI bench-smoke job runs exactly this):

  python benchmarks/check_regression.py \
      --current BENCH_kernel.json --baseline benchmarks/baselines/BENCH_kernel.json
  python benchmarks/check_regression.py \
      --current BENCH_serve.json --baseline benchmarks/baselines/BENCH_serve.json

``--update-baseline`` rewrites the baseline from the current run (commit the
result to move the gate).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import sys


# unit suffix of a latency section name -> scale to microseconds (the common
# currency --floor-us is expressed in)
_UNIT_TO_US = (("_us", 1.0), ("_ms", 1e3), ("_s", 1e6))


def _kernel_latencies(doc: dict) -> dict:
    """{metric: us} from a BENCH_kernel.json document.

    Generic over sections: every dict of scalars under ``results`` whose
    section name ends in a recognized unit suffix contributes metrics named
    ``{section}/{key}``, normalized to microseconds.  A section the candidate
    run omits therefore shows up as missing keys, never as a silent skip."""
    out = {}
    for section, vals in doc["results"].items():
        if not isinstance(vals, dict):
            continue
        for suffix, scale in _UNIT_TO_US:
            if section.endswith(suffix):
                out.update({f"{section}/{k}": float(v) * scale
                            for k, v in vals.items()})
                break
    return out


def _serve_metrics(doc: dict) -> tuple:
    """({latency metric: us}, {throughput metric: value}).

    Latencies are normalized to MICROSECONDS so the shared ``--floor-us``
    noise floor means the same thing for kernel and serve documents.
    """
    r = doc["results"]
    scale = {"decode_ms_tick": 1e3, "mean_latency_s": 1e6, "mean_ttft_s": 1e6,
             "ttft_p50_s": 1e6, "ttft_p95_s": 1e6,
             "e2e_p50_s": 1e6, "e2e_p95_s": 1e6}
    lat = {k: float(r[k]) * s for k, s in scale.items() if r.get(k)}
    thr = {k: float(r[k]) for k in ("decode_tok_s", "prefill_tok_s",
                                    "prefix_hit_rate", "page_saving_ratio")
           if r.get(k)}
    return lat, thr


def extract(doc: dict) -> tuple:
    if doc.get("bench") == "kernel_bench":
        return _kernel_latencies(doc), {}
    if doc.get("bench") == "serve_bench":
        return _serve_metrics(doc)
    raise SystemExit(f"unknown bench document: {doc.get('bench')!r}")


def compare(cur: dict, base: dict, *, threshold: float,
            floor_us: float) -> tuple:
    """(regression records, baseline metrics missing from the current run).

    A metric that silently disappears (backend unregistered, bench filter
    typo) is exactly the blind spot a gate must not have — missing keys are
    reported and fail the gate unless ``--allow-missing``."""
    cur_lat, cur_thr = extract(cur)
    base_lat, base_thr = extract(base)
    missing = sorted((set(base_lat) - set(cur_lat))
                     | (set(base_thr) - set(cur_thr)))
    bad = []
    for key in sorted(set(cur_lat) & set(base_lat)):
        c, b = cur_lat[key], base_lat[key]
        # noise exemption only while BOTH sides are micro-scale: a baseline
        # below the floor must not grant a backend a permanent free pass
        if b < floor_us and c < floor_us:
            continue
        if c > b * (1 + threshold):
            bad.append((key, b, c, c / b - 1, "latency"))
    for key in sorted(set(cur_thr) & set(base_thr)):
        c, b = cur_thr[key], base_thr[key]
        if b <= 0:
            continue
        if c < b * (1 - threshold):
            bad.append((key, b, c, 1 - c / b, "throughput"))
    return bad, missing


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True,
                    help="BENCH_*.json produced by this run")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="max tolerated relative regression (0.20 = 20%%)")
    ap.add_argument("--floor-us", type=float, default=200.0,
                    help="skip latency metrics below this (noise floor)")
    ap.add_argument("--allow-missing", action="store_true",
                    help="do not fail when baseline metrics are absent from "
                         "the current run (e.g. a backend was retired)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="overwrite the baseline with the current run")
    args = ap.parse_args(argv)

    cur_path = pathlib.Path(args.current)
    base_path = pathlib.Path(args.baseline)
    if args.update_baseline:
        base_path.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(cur_path, base_path)
        print(f"[check_regression] baseline updated: {base_path}")
        return 0
    if not base_path.exists():
        print(f"[check_regression] no baseline at {base_path}; pass "
              f"--update-baseline to seed it (not failing)")
        return 0
    cur = json.loads(cur_path.read_text())
    base = json.loads(base_path.read_text())
    if cur.get("bench") != base.get("bench"):
        raise SystemExit("current and baseline are different benches: "
                         f"{cur.get('bench')!r} vs {base.get('bench')!r}")
    env_c, env_b = cur.get("environment", {}), base.get("environment", {})
    if env_c.get("jax") != env_b.get("jax"):
        print(f"[check_regression] jax {env_b.get('jax')} -> "
              f"{env_c.get('jax')}: cross-version point, comparing anyway")

    bad, missing = compare(cur, base, threshold=args.threshold,
                           floor_us=args.floor_us)
    cur_lat, cur_thr = extract(cur)
    base_lat, base_thr = extract(base)
    n_shared = len(set(cur_lat) & set(base_lat)) + len(set(cur_thr)
                                                      & set(base_thr))
    print(f"[check_regression] {base_path.name}: {n_shared} shared metrics "
          f"at threshold {args.threshold:.0%} "
          f"(latency floor {args.floor_us:.0f}us)")
    rc = 0
    if n_shared == 0:
        print("[check_regression] FAIL: nothing to compare — the current "
              "run shares no metrics with the baseline")
        rc = 1
    for key in missing:
        print(f"[check_regression] MISSING from current run: {key}"
              + (" (allowed)" if args.allow_missing else ""))
    if missing and not args.allow_missing:
        print("[check_regression] FAIL: baseline metrics vanished — pass "
              "--allow-missing or --update-baseline if intentional")
        rc = 1
    for key, b, c, rel, kind in bad:
        print(f"[check_regression] REGRESSION {kind} {key}: "
              f"{b:.1f} -> {c:.1f} (+{rel:.0%})")
    if bad:
        rc = 1
    if rc == 0:
        print("[check_regression] OK — no regression beyond threshold")
    return rc


if __name__ == "__main__":
    sys.exit(main())
