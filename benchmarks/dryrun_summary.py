"""Generate experiments/dryrun_summary.md from the dry-run artifacts."""
from __future__ import annotations

import json
import pathlib

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def main():
    rows = []
    for p in sorted(DRYRUN.glob("*.json")):
        r = json.loads(p.read_text())
        if "skipped" in r:
            rows.append((r["arch"], r["shape"], r["mesh"], "SKIP", r["skipped"],
                         "", "", "", ""))
            continue
        if "error" in r:
            rows.append((r["arch"], r["shape"], r["mesh"], "FAIL",
                         r["error"][:60], "", "", "", ""))
            continue
        temp = (r["memory"]["temp_bytes"] or 0) / 1e9
        args = (r["memory"]["argument_bytes"] or 0) / 1e9
        fits = "✅" if temp + min(args, 16) < 16 or temp < 16 else "⚠"
        rows.append((r["arch"], r["shape"], r["mesh"], "OK",
                     f"{r['compile_s']:.0f}s",
                     f"{temp:.1f}",
                     f"{r['flops_per_device']:.2e}",
                     f"{r['bytes_per_device']:.2e}",
                     f"{r['collective_bytes_per_device'].get('total', 0):.2e}"))
    hdr = ("| arch | shape | mesh | status | compile | temp GB/dev | "
           "flops/dev | bytes/dev | coll B/dev |\n" + "|---" * 9 + "|\n")
    body = "\n".join("| " + " | ".join(str(c) for c in row) + " |"
                     for row in rows)
    out = DRYRUN.parent / "dryrun_summary.md"
    out.write_text(hdr + body + "\n")
    n_ok = sum(1 for r in rows if r[3] == "OK")
    n_skip = sum(1 for r in rows if r[3] == "SKIP")
    n_fail = sum(1 for r in rows if r[3] == "FAIL")
    print(f"dryrun_summary,cells={len(rows)},ok={n_ok},skip={n_skip},"
          f"fail={n_fail},written={out}")


if __name__ == "__main__":
    main()
