"""Kernel-level benchmark (paper Fig. 4 analogue).

This container has no TPU, so two complementary measurements are reported:
  1. CPU wall time of the *semantic* implementations (interpret-mode Pallas
     kernels at small shapes) — verifies the machinery end to end and gives
     directional per-backend cost.  ``--backend all`` (the default) sweeps
     every backend registered in ``repro.attention`` that is capable of the
     benchmarked mode, driven from the registry — new backends show up here
     with zero bench changes;
  2. the analytic latency projection at the paper's shapes on TPU v5e
     (197 TFLOP/s bf16, 819 GB/s HBM): t = max(flops/peak, bytes/bw) from the
     §3.3 model — the roofline-derived Fig. 4 twin, per (g, B_K, T, N).

``--pass fwd|bwd|fwdbwd`` selects what is timed: ``fwd`` the inference-path
calls (historic behavior, default), ``bwd`` a ``jax.grad`` step through every
differentiable train-capable backend (forward + backward, the training-step
cost), ``fwdbwd`` both.  Backward rows land in a separate ``bwd_ms`` results
section so the regression gate tracks training-path latency per backend —
fused-backward backends (``fsa``, ``flash_*``) are timed through their Pallas
backward kernels, twin-fallback backends through the XLA VJP.

``--json-out PATH`` writes the rows as a BENCH_kernel.json trajectory point
(shared writer in ``benchmarks/results.py``; per-backend keys, so
``benchmarks/check_regression.py`` can diff them against a committed
baseline); ``--tiny`` shrinks shapes for the CI bench-smoke job.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

try:
    from benchmarks import analytic_model as am
    from benchmarks.results import write_results, write_telemetry_snapshot
except ImportError:      # script-style run: benchmarks/ itself is sys.path[0]
    import analytic_model as am
    from results import write_results, write_telemetry_snapshot
from repro import telemetry
from repro.attention import NSAConfig, list_backends, nsa_attention
from repro.core import apply_gates, init_nsa_params

V5E_FLOPS = 197e12
V5E_BW = 819e9


def time_call(fn, *args, reps=5):
    """Min-of-reps latency in us — min is far stabler than mean against
    scheduler spikes on shared runners, which matters because
    check_regression.py gates on these numbers at a 20% threshold."""
    fn(*args)  # compile/warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


def _nsa_state(n, g, h_k, d, b_k, t_sel):
    cfg = NSAConfig(block_size=b_k, num_selected=t_sel, q_block_size=32,
                    cmp_block_size=8, cmp_stride=4, window_size=2 * b_k,
                    min_seq_for_sparse=1)
    h = g * h_k
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    p = init_nsa_params(ks[0], 64, h, d, cfg)
    gates = apply_gates(p, jax.random.normal(ks[1], (n, 64)))
    q = jax.random.normal(ks[2], (n, h, d))
    k = jax.random.normal(ks[3], (n, h_k, d))
    v = jax.random.normal(ks[4], (n, h_k, d))
    return cfg, p, gates, q, k, v


def _paged_state(b_k, t_sel, h_k, g, d, slots, max_pages):
    cfg = NSAConfig(block_size=b_k, num_selected=t_sel, cmp_block_size=8,
                    cmp_stride=4, window_size=2 * b_k, q_block_size=32)
    h = h_k * g
    num_pages = slots * max_pages + 1
    n_cmp = cfg.num_cmp_blocks(max_pages * b_k)
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    state = {
        "gates": jax.nn.softmax(jax.random.normal(ks[1], (slots, h, 3)), -1),
        "q": jax.random.normal(ks[0], (slots, h, d)),
        "k_pages": jax.random.normal(ks[2], (num_pages, b_k, h_k, d)),
        "v_pages": jax.random.normal(ks[3], (num_pages, b_k, h_k, d)),
        "cmp_k": jax.random.normal(ks[4], (slots, n_cmp, h_k, d)),
        "cmp_v": jax.random.normal(ks[5], (slots, n_cmp, h_k, d)),
        "tables": (1 + jnp.arange(slots * max_pages, dtype=jnp.int32)
                   ).reshape(slots, max_pages),
        "pos": jnp.full((slots,), max_pages * b_k - 1, jnp.int32),
    }
    return cfg, state


def registry_rows(backends="all", n=256, g=2, h_k=2, d=32, b_k=16, t_sel=4,
                  slots=4, max_pages=8, bench_pass="fwd"):
    """Latency rows per (capable backend, benchmarked mode), driven from
    the ``repro.attention`` registry.  Backends whose declared ``min_g``
    exceeds the sweep's g are benchmarked at their minimum supported group
    size (tagged in the row) instead of being skipped silently.

    Returns ``(fwd_rows, bwd_rows)``; either may be empty depending on
    ``bench_pass``.  Backward rows time one whole ``jax.grad`` step (forward
    + backward) of a scalar loss through ``nsa_attention(mode="train")`` for
    every backend declaring ``differentiable`` — so fused-backward backends
    are measured through their Pallas backward kernels and the rest through
    the XLA twin fallback."""
    want = None if backends in ("all", None) else set(backends.split(","))
    if want is not None:
        unknown = want - set(list_backends())
        if unknown:
            raise SystemExit(f"unknown backend(s) {sorted(unknown)}; "
                             f"registered: {', '.join(list_backends())}")
    time_fwd = bench_pass in ("fwd", "fwdbwd")
    time_bwd = bench_pass in ("bwd", "fwdbwd")
    rows = []
    bwd_rows = []
    states = {}
    paged = {}

    def nsa_bench(name, caps):
        g_eff = max(g, caps.min_g)
        if g_eff not in states:
            states[g_eff] = _nsa_state(n, g_eff, h_k, d, b_k, t_sel)
        cfg, p, gates, q, k, v = states[g_eff]
        fn = jax.jit(lambda gates, q, k, v: nsa_attention(
            p, gates, q, k, v, cfg=cfg, mode="prefill", backend=name,
            needs_grad=False))
        tag = f"@g{g_eff}" if g_eff != g else ""
        return {"backend": name, "mode": "prefill", "g": g_eff,
                "key": f"prefill/{name}{tag}",
                "us": time_call(fn, gates, q, k, v)}

    def flash_bench(name, algorithm):
        if g not in states:
            states[g] = _nsa_state(n, g, h_k, d, b_k, t_sel)
        cfg, p, gates, q, k, v = states[g]
        fn = jax.jit(lambda q, k, v: nsa_attention(
            None, None, q, k, v, cfg=cfg, mode="prefill", backend=name,
            algorithm=algorithm))
        return {"backend": name, "mode": f"prefill/{algorithm}", "g": g,
                "key": f"{algorithm}/{name}", "us": time_call(fn, q, k, v)}

    def nsa_grad_bench(name, caps):
        g_eff = max(g, caps.min_g)
        if g_eff not in states:
            states[g_eff] = _nsa_state(n, g_eff, h_k, d, b_k, t_sel)
        cfg, p, gates, q, k, v = states[g_eff]

        def loss(q, k, v):
            out = nsa_attention(p, gates, q, k, v, cfg=cfg, mode="train",
                                backend=name, needs_grad=True)
            return jnp.sum(out * out)

        fn = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))
        tag = f"@g{g_eff}" if g_eff != g else ""
        return {"backend": name, "mode": "train", "g": g_eff,
                "key": f"train/{name}{tag}",
                "ms": time_call(fn, q, k, v) / 1e3}

    def flash_grad_bench(name, algorithm):
        if g not in states:
            states[g] = _nsa_state(n, g, h_k, d, b_k, t_sel)
        cfg, p, gates, q, k, v = states[g]

        def loss(q, k, v):
            out = nsa_attention(None, None, q, k, v, cfg=cfg, mode="train",
                                backend=name, algorithm=algorithm,
                                needs_grad=True)
            return jnp.sum(out * out)

        fn = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))
        return {"backend": name, "mode": f"train/{algorithm}", "g": g,
                "key": f"{algorithm}/{name}",
                "ms": time_call(fn, q, k, v) / 1e3}

    def paged_bench(name):
        if not paged:
            paged["state"] = _paged_state(b_k, t_sel, h_k, g, d, slots,
                                          max_pages)
        cfg, st = paged["state"]
        fn = jax.jit(lambda q, ck, cv: nsa_attention(
            None, st["gates"], q, st["k_pages"], st["v_pages"],
            {"page_tables": st["tables"], "cmp_k": ck, "cmp_v": cv,
             "pos": st["pos"]},
            cfg=cfg, mode="paged_decode", backend=name))
        return {"backend": name, "mode": "paged_decode", "g": g,
                "key": f"paged_decode/{name}",
                "us": time_call(fn, st["q"], st["cmp_k"], st["cmp_v"])}

    for name, caps in list_backends().items():
        if want is not None and name not in want:
            continue
        if time_fwd:
            if "nsa" in caps.algorithms and "prefill" in caps.modes:
                rows.append(nsa_bench(name, caps))
            if "full" in caps.algorithms and "prefill" in caps.modes:
                rows.append(flash_bench(name, "full"))
            if "sliding" in caps.algorithms and "prefill" in caps.modes:
                rows.append(flash_bench(name, "sliding"))
            if "paged_decode" in caps.modes:
                rows.append(paged_bench(name))
        if time_bwd and caps.differentiable and "train" in caps.modes:
            if "nsa" in caps.algorithms:
                bwd_rows.append(nsa_grad_bench(name, caps))
            if "full" in caps.algorithms:
                bwd_rows.append(flash_grad_bench(name, "full"))
            if "sliding" in caps.algorithms:
                bwd_rows.append(flash_grad_bench(name, "sliding"))
    return rows, bwd_rows


def v5e_projection():
    """Analytic per-(config) selected-attention latency on one v5e chip."""
    rows = []
    d, h_k = 128, 4
    for n in (8192, 16384, 32768, 65536):
        for b_k, t in ((64, 16), (128, 8)):
            for g in (1, 2, 4, 8):
                h = g * h_k
                t_eff = min(t, n // b_k)
                fsa_t = max(am.fsa_flops(d, n, h, h_k, b_k, t_eff) / V5E_FLOPS,
                            am.fsa_memory_bytes(d, n, h, h_k, t_eff) / V5E_BW)
                nsa_t = max(am.nsa_flops(d, n, h, h_k, b_k, t_eff) / V5E_FLOPS,
                            am.nsa_memory_bytes(d, n, h, h_k, b_k, t_eff) / V5E_BW)
                # full attention: flops 4*N^2*d*h? causal half: 2*N^2*d*h
                full_fl = 2 * n * n * d * h
                full_by = 2 * n * (h + 2 * h_k) * d * (1 + n // 2048)
                full_t = max(full_fl / V5E_FLOPS, full_by / V5E_BW)
                rows.append({"N": n, "B_K": b_k, "T": t, "g": g,
                             "fsa_us": fsa_t * 1e6, "nsa_us": nsa_t * 1e6,
                             "full_us": full_t * 1e6,
                             "speedup_vs_nsa": nsa_t / fsa_t,
                             "speedup_vs_full": full_t / fsa_t})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="all",
                    help="'all' (sweep every capable registered backend) or "
                         "a comma-separated list of registry names")
    ap.add_argument("--json-out", default=None,
                    help="write a BENCH_kernel.json trajectory point here")
    ap.add_argument("--pass", dest="bench_pass", default="fwd",
                    choices=("fwd", "bwd", "fwdbwd"),
                    help="time forward calls, jax.grad training steps "
                         "(fwd+bwd through the backend's VJP), or both")
    ap.add_argument("--tiny", action="store_true",
                    help="CI bench-smoke shapes (smaller N)")
    ap.add_argument("--telemetry-snapshot", default=None,
                    help="enable global telemetry and write its snapshot "
                         "(per-backend dispatch counters) here")
    args = ap.parse_args(argv)

    if args.telemetry_snapshot:
        telemetry.enable()
    shape = dict(n=64, b_k=8, t_sel=2, slots=2, max_pages=4) if args.tiny \
        else {}
    rows, bwd_rows = registry_rows(args.backend, bench_pass=args.bench_pass,
                                   **shape)
    for r in rows:
        print(f"kernel_bench,{r['key']}_cpu_interpret,{r['us']:.0f}")
    for r in bwd_rows:
        print(f"kernel_bench,bwd/{r['key']}_cpu_interpret_ms,{r['ms']:.2f}")
    proj = v5e_projection()
    print("kernel_bench_v5e,N,B_K,T,g,fsa_us,nsa_us,full_us,speedup_vs_nsa,"
          "speedup_vs_full")
    for r in proj:
        print(f"kernel_bench_v5e,{r['N']},{r['B_K']},{r['T']},{r['g']},"
              f"{r['fsa_us']:.1f},{r['nsa_us']:.1f},{r['full_us']:.1f},"
              f"{r['speedup_vs_nsa']:.2f},{r['speedup_vs_full']:.2f}")
    if args.json_out:
        payload = {
            "v5e_projection": proj,
            "tiny": args.tiny,
            "pass": args.bench_pass,
        }
        if rows:
            payload["cpu_interpret_us"] = {r["key"]: r["us"] for r in rows}
            payload["backend_rows"] = rows
        if bwd_rows:
            payload["bwd_ms"] = {r["key"]: r["ms"] for r in bwd_rows}
            payload["bwd_rows"] = bwd_rows
        write_results(args.json_out, "kernel_bench", payload)
    if args.telemetry_snapshot:
        write_telemetry_snapshot(args.telemetry_snapshot,
                                 {"global": telemetry.registry().snapshot()},
                                 source="kernel_bench")
    return rows, bwd_rows


if __name__ == "__main__":
    main()
