"""Kernel-level benchmark (paper Fig. 4 analogue).

This container has no TPU, so two complementary measurements are reported:
  1. CPU wall time of the *semantic* implementations (interpret-mode Pallas
     kernels at small shapes) — verifies the machinery end to end and gives
     directional per-kernel cost;
  2. the analytic latency projection at the paper's shapes on TPU v5e
     (197 TFLOP/s bf16, 819 GB/s HBM): t = max(flops/peak, bytes/bw) from the
     §3.3 model — the roofline-derived Fig. 4 twin, per (g, B_K, T, N).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import analytic_model as am
from repro.core import NSAConfig
from repro.core.selection import select_blocks
from repro.kernels import ops

V5E_FLOPS = 197e12
V5E_BW = 819e9


def time_call(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def cpu_kernel_times(n=256, g=2, h_k=2, d=32, b_k=16, t_sel=4):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    h = g * h_k
    q = jax.random.normal(ks[0], (n, h, d))
    k = jax.random.normal(ks[1], (n, h_k, d))
    v = jax.random.normal(ks[2], (n, h_k, d))
    scores = jax.random.uniform(ks[3], (n, h_k, n // b_k))
    base = NSAConfig(block_size=b_k, num_selected=t_sel, q_block_size=32,
                     cmp_block_size=8, cmp_stride=4)
    idx, valid = select_blocks(scores, jnp.arange(n), base, n)
    rows = []
    for kern in ("fsa", "fsa_faithful", "nsa"):
        cfg = NSAConfig(**{**base.__dict__, "kernel": kern})
        fn = jax.jit(lambda q, k, v, c=cfg: ops.selected_attention(
            q, k, v, idx, valid, c))
        rows.append((f"selected/{kern}", time_call(fn, q, k, v)))
    fn = jax.jit(lambda q, k, v: ops.full_attention(q, k, v, base))
    rows.append(("full/flash", time_call(fn, q, k, v)))
    return rows


def v5e_projection():
    """Analytic per-(config) selected-attention latency on one v5e chip."""
    rows = []
    d, h_k = 128, 4
    for n in (8192, 16384, 32768, 65536):
        for b_k, t in ((64, 16), (128, 8)):
            for g in (1, 2, 4, 8):
                h = g * h_k
                t_eff = min(t, n // b_k)
                fsa_t = max(am.fsa_flops(d, n, h, h_k, b_k, t_eff) / V5E_FLOPS,
                            am.fsa_memory_bytes(d, n, h, h_k, t_eff) / V5E_BW)
                nsa_t = max(am.nsa_flops(d, n, h, h_k, b_k, t_eff) / V5E_FLOPS,
                            am.nsa_memory_bytes(d, n, h, h_k, b_k, t_eff) / V5E_BW)
                # full attention: flops 4*N^2*d*h? causal half: 2*N^2*d*h
                full_fl = 2 * n * n * d * h
                full_by = 2 * n * (h + 2 * h_k) * d * (1 + n // 2048)
                full_t = max(full_fl / V5E_FLOPS, full_by / V5E_BW)
                rows.append({"N": n, "B_K": b_k, "T": t, "g": g,
                             "fsa_us": fsa_t * 1e6, "nsa_us": nsa_t * 1e6,
                             "full_us": full_t * 1e6,
                             "speedup_vs_nsa": nsa_t / fsa_t,
                             "speedup_vs_full": full_t / fsa_t})
    return rows


def main():
    for name, us in cpu_kernel_times():
        print(f"kernel_bench,{name}_cpu_interpret,{us:.0f}")
    print("kernel_bench_v5e,N,B_K,T,g,fsa_us,nsa_us,full_us,speedup_vs_nsa,"
          "speedup_vs_full")
    for r in v5e_projection():
        print(f"kernel_bench_v5e,{r['N']},{r['B_K']},{r['T']},{r['g']},"
              f"{r['fsa_us']:.1f},{r['nsa_us']:.1f},{r['full_us']:.1f},"
              f"{r['speedup_vs_nsa']:.2f},{r['speedup_vs_full']:.2f}")


if __name__ == "__main__":
    main()
