"""Kernel-level benchmark (paper Fig. 4 analogue).

This container has no TPU, so two complementary measurements are reported:
  1. CPU wall time of the *semantic* implementations (interpret-mode Pallas
     kernels at small shapes) — verifies the machinery end to end and gives
     directional per-kernel cost;
  2. the analytic latency projection at the paper's shapes on TPU v5e
     (197 TFLOP/s bf16, 819 GB/s HBM): t = max(flops/peak, bytes/bw) from the
     §3.3 model — the roofline-derived Fig. 4 twin, per (g, B_K, T, N).

``--json-out PATH`` writes the rows as a BENCH_kernel.json trajectory point
(shared writer in ``benchmarks/results.py``); ``--tiny`` shrinks shapes for
the CI bench-smoke job.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

try:
    from benchmarks import analytic_model as am
    from benchmarks.results import write_results
except ImportError:      # script-style run: benchmarks/ itself is sys.path[0]
    import analytic_model as am
    from results import write_results
from repro.core import NSAConfig
from repro.core.selection import select_blocks
from repro.kernels import ops

V5E_FLOPS = 197e12
V5E_BW = 819e9


def time_call(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def cpu_kernel_times(n=256, g=2, h_k=2, d=32, b_k=16, t_sel=4):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    h = g * h_k
    q = jax.random.normal(ks[0], (n, h, d))
    k = jax.random.normal(ks[1], (n, h_k, d))
    v = jax.random.normal(ks[2], (n, h_k, d))
    scores = jax.random.uniform(ks[3], (n, h_k, n // b_k))
    base = NSAConfig(block_size=b_k, num_selected=t_sel, q_block_size=32,
                     cmp_block_size=8, cmp_stride=4)
    idx, valid = select_blocks(scores, jnp.arange(n), base, n)
    rows = []
    for kern in ("fsa", "fsa_faithful", "nsa"):
        cfg = NSAConfig(**{**base.__dict__, "kernel": kern})
        fn = jax.jit(lambda q, k, v, c=cfg: ops.selected_attention(
            q, k, v, idx, valid, c))
        rows.append((f"selected/{kern}", time_call(fn, q, k, v)))
    fn = jax.jit(lambda q, k, v: ops.full_attention(q, k, v, base))
    rows.append(("full/flash", time_call(fn, q, k, v)))
    rows.append(("paged_decode/kernel",
                 paged_decode_time(b_k=b_k, t_sel=t_sel, h_k=h_k, g=g, d=d)))
    return rows


def paged_decode_time(*, b_k=16, t_sel=4, h_k=2, g=2, d=32, slots=4,
                      max_pages=8):
    """Interpret-mode latency of one batched paged-decode dispatch."""
    cfg = NSAConfig(block_size=b_k, num_selected=t_sel, cmp_block_size=8,
                    cmp_stride=4, window_size=2 * b_k, q_block_size=32)
    h = h_k * g
    num_pages = slots * max_pages + 1
    n_cmp = cfg.num_cmp_blocks(max_pages * b_k)
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    q = jax.random.normal(ks[0], (slots, h, d))
    gates = jax.nn.softmax(jax.random.normal(ks[1], (slots, h, 3)), -1)
    k_pages = jax.random.normal(ks[2], (num_pages, b_k, h_k, d))
    v_pages = jax.random.normal(ks[3], (num_pages, b_k, h_k, d))
    cmp_k = jax.random.normal(ks[4], (slots, n_cmp, h_k, d))
    cmp_v = jax.random.normal(ks[5], (slots, n_cmp, h_k, d))
    tables = (1 + jnp.arange(slots * max_pages, dtype=jnp.int32)
              ).reshape(slots, max_pages)
    pos = jnp.full((slots,), max_pages * b_k - 1, jnp.int32)
    fn = jax.jit(lambda q, ck, cv: ops.paged_decode_attention_batched(
        gates, q, k_pages, v_pages, tables, ck, cv, pos, cfg,
        use_kernel=True))
    return time_call(fn, q, cmp_k, cmp_v)


def v5e_projection():
    """Analytic per-(config) selected-attention latency on one v5e chip."""
    rows = []
    d, h_k = 128, 4
    for n in (8192, 16384, 32768, 65536):
        for b_k, t in ((64, 16), (128, 8)):
            for g in (1, 2, 4, 8):
                h = g * h_k
                t_eff = min(t, n // b_k)
                fsa_t = max(am.fsa_flops(d, n, h, h_k, b_k, t_eff) / V5E_FLOPS,
                            am.fsa_memory_bytes(d, n, h, h_k, t_eff) / V5E_BW)
                nsa_t = max(am.nsa_flops(d, n, h, h_k, b_k, t_eff) / V5E_FLOPS,
                            am.nsa_memory_bytes(d, n, h, h_k, b_k, t_eff) / V5E_BW)
                # full attention: flops 4*N^2*d*h? causal half: 2*N^2*d*h
                full_fl = 2 * n * n * d * h
                full_by = 2 * n * (h + 2 * h_k) * d * (1 + n // 2048)
                full_t = max(full_fl / V5E_FLOPS, full_by / V5E_BW)
                rows.append({"N": n, "B_K": b_k, "T": t, "g": g,
                             "fsa_us": fsa_t * 1e6, "nsa_us": nsa_t * 1e6,
                             "full_us": full_t * 1e6,
                             "speedup_vs_nsa": nsa_t / fsa_t,
                             "speedup_vs_full": full_t / fsa_t})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-out", default=None,
                    help="write a BENCH_kernel.json trajectory point here")
    ap.add_argument("--tiny", action="store_true",
                    help="CI bench-smoke shapes (smaller N)")
    args = ap.parse_args(argv)

    shape = dict(n=64, b_k=8, t_sel=2) if args.tiny else {}
    cpu_rows = cpu_kernel_times(**shape)
    for name, us in cpu_rows:
        print(f"kernel_bench,{name}_cpu_interpret,{us:.0f}")
    proj = v5e_projection()
    print("kernel_bench_v5e,N,B_K,T,g,fsa_us,nsa_us,full_us,speedup_vs_nsa,"
          "speedup_vs_full")
    for r in proj:
        print(f"kernel_bench_v5e,{r['N']},{r['B_K']},{r['T']},{r['g']},"
              f"{r['fsa_us']:.1f},{r['nsa_us']:.1f},{r['full_us']:.1f},"
              f"{r['speedup_vs_nsa']:.2f},{r['speedup_vs_full']:.2f}")
    if args.json_out:
        write_results(args.json_out, "kernel_bench", {
            "cpu_interpret_us": dict(cpu_rows),
            "v5e_projection": proj,
            "tiny": args.tiny,
        })


if __name__ == "__main__":
    main()
