"""repro.core — Native Sparse Attention algorithm + FSA fast paths (paper core)."""
from repro.core.attention import (
    compressed_and_selection,
    init_nsa_params,
    nsa_attention,
)
from repro.core.gating import apply_gates, init_gate_params
from repro.core.nsa_config import NSAConfig
from repro.core.reference import (
    full_attention_ref,
    nsa_attention_ref,
    selected_attention_ref,
    sliding_attention_ref,
)
from repro.core.sparse import nsa_attention_sparse, nsa_decode_step

__all__ = [
    "NSAConfig",
    "nsa_attention",
    "nsa_attention_ref",
    "nsa_attention_sparse",
    "nsa_decode_step",
    "init_nsa_params",
    "init_gate_params",
    "apply_gates",
    "compressed_and_selection",
    "full_attention_ref",
    "selected_attention_ref",
    "sliding_attention_ref",
]
