"""Dense-mask pure-jnp oracles for every attention branch.

These are the ground truth for all kernels and sparse fast paths.  They
materialise (Q, N) masks, so use them only at test scales.

All functions are unbatched — q: (N, h, d), k/v: (N, h_k, d); vmap for batch.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import compression, selection
from repro.core.nsa_config import NSAConfig


def _safe_softmax(scores: jnp.ndarray, mask: jnp.ndarray):
    """Masked softmax that returns zeros (not NaN) for fully-masked rows.

    Returns (probs, lse) with lse = log-sum-exp over unmasked entries.
    """
    scores = jnp.where(mask, scores, selection.NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    m = jnp.maximum(m, selection.NEG_INF / 2)  # keep finite when all masked
    e = jnp.exp(scores - m) * mask
    s = e.sum(axis=-1, keepdims=True)
    probs = e / jnp.maximum(s, 1e-30)
    lse = jnp.squeeze(m, -1) + jnp.log(jnp.maximum(jnp.squeeze(s, -1), 1e-30))
    return probs, lse


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q: (Q, h, d), k: (S, h_k, d) -> (Q, h, S) with GQA head mapping."""
    n, h, d = q.shape
    h_k = k.shape[1]
    g = h // h_k
    qg = q.reshape(n, h_k, g, d)
    s = jnp.einsum("qkgd,skd->qkgs", qg.astype(jnp.float32), k.astype(jnp.float32))
    return s.reshape(n, h, -1) / jnp.sqrt(d).astype(jnp.float32)


def _gqa_out(probs: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """probs: (Q, h, S), v: (S, h_k, dv) -> (Q, h, dv)."""
    n, h, _ = probs.shape
    h_k = v.shape[1]
    g = h // h_k
    pg = probs.reshape(n, h_k, g, -1)
    o = jnp.einsum("qkgs,skd->qkgd", pg, v.astype(jnp.float32))
    return o.reshape(n, h, v.shape[-1])


def full_attention_ref(q, k, v, *, causal: bool = True):
    """Standard (causal) full attention oracle."""
    n = q.shape[0]
    s = k.shape[0]
    scores = _gqa_scores(q, k)
    if causal:
        mask = jnp.arange(n)[:, None] + (s - n) >= jnp.arange(s)[None, :]
    else:
        mask = jnp.ones((n, s), bool)
    probs, _ = _safe_softmax(scores, mask[:, None, :])
    return _gqa_out(probs, v).astype(q.dtype)


def sliding_attention_ref(q, k, v, window: int):
    """Causal sliding-window oracle (window includes the current token)."""
    n, s = q.shape[0], k.shape[0]
    pos_q = jnp.arange(n) + (s - n)
    pos_k = jnp.arange(s)
    mask = (pos_q[:, None] >= pos_k[None, :]) & (pos_q[:, None] - pos_k[None, :] < window)
    probs, _ = _safe_softmax(_gqa_scores(q, k), mask[:, None, :])
    return _gqa_out(probs, v).astype(q.dtype)


def compressed_attention_ref(params, q, k, v, cfg: NSAConfig, q_pos=None):
    """Compressed branch oracle. Returns (out, p_cmp) — p_cmp feeds selection."""
    n = q.shape[0]
    k_cmp, v_cmp = compression.compress_kv(params, k, v, cfg)
    if q_pos is None:
        q_pos = jnp.arange(n) + (k.shape[0] - n)
    vis = compression.cmp_visibility(q_pos, k_cmp.shape[0], cfg)
    probs, _ = _safe_softmax(_gqa_scores(q, k_cmp), vis[:, None, :])
    return _gqa_out(probs, v_cmp).astype(q.dtype), probs


def selected_attention_ref(q, k, v, block_idx, block_valid, cfg: NSAConfig, q_pos=None):
    """Selected branch oracle via a dense (Q, h_k, S) mask.

    block_idx/block_valid: (Q, h_k, T) from selection.select_blocks.
    Token s is visible to query t iff s <= t and floor(s/B_K) is selected.
    """
    n, s = q.shape[0], k.shape[0]
    h_k = k.shape[1]
    if q_pos is None:
        q_pos = jnp.arange(n) + (s - n)
    kv_blk = jnp.arange(s) // cfg.block_size                          # (S,)
    sel = (block_idx[..., None] == kv_blk) & block_valid[..., None]   # (Q,h_k,T,S)
    mask = sel.any(axis=2)                                            # (Q, h_k, S)
    mask &= q_pos[:, None, None] >= jnp.arange(s)[None, None, :]
    g = q.shape[1] // h_k
    mask_h = jnp.repeat(mask, g, axis=1)                              # (Q, h, S)
    probs, lse = _safe_softmax(_gqa_scores(q, k), mask_h)
    return _gqa_out(probs, v).astype(q.dtype), lse


def nsa_attention_ref(params, x_gates, q, k, v, cfg: NSAConfig):
    """Full NSA oracle: compressed + selected + sliding combined by gates.

    x_gates: (N, h, 3) sigmoid gate values (computed by the caller's gate MLP).
    Returns (N, h, d).
    """
    n = q.shape[0]
    out_cmp, p_cmp = compressed_attention_ref(params, q, k, v, cfg)
    sel_map = jnp.asarray(
        compression.cmp_to_sel_map(p_cmp.shape[-1], cfg.num_kv_blocks(n), cfg)
    )
    g = q.shape[1] // k.shape[1]
    scores = selection.importance_scores(p_cmp, sel_map, g)
    idx, valid = selection.select_blocks(scores, jnp.arange(n), cfg, n)
    out_sel, _ = selected_attention_ref(q, k, v, idx, valid, cfg)
    out_win = sliding_attention_ref(q, k, v, cfg.window_size)
    gates = x_gates.astype(jnp.float32)
    out = (
        gates[..., 0:1] * out_cmp.astype(jnp.float32)
        + gates[..., 1:2] * out_sel.astype(jnp.float32)
        + gates[..., 2:3] * out_win.astype(jnp.float32)
    )
    return out.astype(q.dtype)
