"""NSA / FSA hyper-parameter bundle.

Notation follows the paper (Table 1):
  N       sequence length
  d_K/d_V head dims (uniform d in practice)
  h       number of query heads
  h_K     number of KV heads,  g = h / h_K  (GQA group size)
  T       number of selected KV blocks per query token (``num_selected``)
  B_K     KV block size (``block_size``)
  B_Q     FSA query-batch (query-block) size (``q_block_size``)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class NSAConfig:
    """Hyper-parameters of the NSA sparse-attention algorithm + FSA kernel knobs."""

    # --- NSA algorithm hyper-parameters (paper defaults: B_K=64, T=16) ---
    block_size: int = 64          # B_K: tokens per selected KV block
    num_selected: int = 16        # T: top-k selected blocks per query token
    cmp_block_size: int = 32      # l: compression block length
    cmp_stride: int = 16          # d: compression stride (overlapping blocks)
    window_size: int = 512        # sliding-window branch width
    num_init_blocks: int = 1      # forced-selected initial blocks
    num_local_blocks: int = 2     # forced-selected local (trailing) blocks

    # --- FSA kernel knobs (TPU) ---
    q_block_size: int = 128       # B_Q: query tokens per FSA batch (MXU M dim)
    kernel: str = "fsa"           # fsa | fsa_faithful | nsa | reference
    interpret: bool = True        # Pallas interpret mode (no TPU in container)

    # --- paged-decode (serving) kernel knobs ---
    # paged_kernel picks the batched decode implementation on paged storage:
    # True -> the Pallas kernel in kernels/paged_decode.py (slots folded into
    # the MXU M dim, kv index_map composed through the page table);
    # False -> the vmapped gather reference.  paged_slot_block is the number
    # of slots folded per M block (0 = auto: fill M to >= 8 rows).
    paged_kernel: bool = True
    paged_slot_block: int = 0

    # --- sparse (XLA) path strategy for the selected branch ---
    # "union":  FSA organization in XLA ops — per query chunk, gather the
    #           union of selected KV blocks ONCE and mask (block-batched,
    #           like the kernel).  Production default.
    # "gather": naive per-token gather of T blocks (each token re-fetches its
    #           blocks) — the vanilla-NSA-style baseline for §Perf.
    selected_impl: str = "union"

    # --- branch toggles (full-attention fallback for short sequences) ---
    min_seq_for_sparse: int = 256  # below this, dense attention is used

    def num_kv_blocks(self, seq_len: int) -> int:
        return max(1, (seq_len + self.block_size - 1) // self.block_size)

    def num_cmp_blocks(self, seq_len: int) -> int:
        if seq_len < self.cmp_block_size:
            return 1
        return (seq_len - self.cmp_block_size) // self.cmp_stride + 1

    def effective_T(self, seq_len: int) -> int:
        """T clamped to the number of KV blocks (short sequences)."""
        return min(self.num_selected, self.num_kv_blocks(seq_len))

    def validate(self) -> None:
        assert self.block_size % 8 == 0, "B_K must be TPU-sublane aligned"
        assert self.q_block_size % 8 == 0, "B_Q must be TPU-sublane aligned"
        assert self.cmp_block_size % self.cmp_stride == 0
        assert self.num_init_blocks >= 1 and self.num_local_blocks >= 1
