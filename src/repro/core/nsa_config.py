"""NSA / FSA hyper-parameter bundle.

``NSAConfig`` carries the *algorithm* hyper-parameters of NSA (what is
computed); ``KernelPolicy`` carries the *implementation* bundle (which
registered ``repro.attention`` backend runs each mode, plus kernel tuning
knobs).  The two are deliberately separate: changing the policy must never
change the math.

Notation follows the paper (Table 1):
  N       sequence length
  d_K/d_V head dims (uniform d in practice)
  h       number of query heads
  h_K     number of KV heads,  g = h / h_K  (GQA group size)
  T       number of selected KV blocks per query token (``num_selected``)
  B_K     KV block size (``block_size``)
  B_Q     FSA query-batch (query-block) size (``q_block_size``)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Implementation bundle: which ``repro.attention`` backend runs each
    mode, plus kernel tuning knobs.  Swapping policies never changes the
    computed function — only how (and how fast) it is computed.

    ``"auto"`` defers the choice to ``repro.attention.resolve``, which picks
    the best *capable* backend for the request's shape/mode/platform.
    """

    backend: str = "auto"          # train/prefill backend (registry name)
    decode_backend: str = "auto"   # dense-cache decode backend
    paged_backend: str = "auto"    # paged-decode (serving) backend

    # --- kernel tuning knobs ---
    q_block_size: int = 128        # B_Q: query tokens per FSA batch (MXU M dim)
    interpret: bool = True         # Pallas interpret mode (no TPU in container)
    # slots folded per M block in the paged-decode kernel (0 = auto: fill the
    # MXU M dim to >= 8 rows)
    paged_slot_block: int = 0


# legacy selected_impl values -> registry backend names (public:
# repro.attention.api derives its legacy-alias table from this)
SELECTED_IMPL_TO_BACKEND = {"union": "sparse_union", "gather": "sparse_gather"}


@dataclasses.dataclass(frozen=True, init=False)
class NSAConfig:
    """Hyper-parameters of the NSA sparse-attention algorithm + the
    ``KernelPolicy`` implementation bundle (see module docstring)."""

    # --- NSA algorithm hyper-parameters (paper defaults: B_K=64, T=16) ---
    block_size: int = 64          # B_K: tokens per selected KV block
    num_selected: int = 16        # T: top-k selected blocks per query token
    cmp_block_size: int = 32      # l: compression block length
    cmp_stride: int = 16          # d: compression stride (overlapping blocks)
    window_size: int = 512        # sliding-window branch width
    num_init_blocks: int = 1      # forced-selected initial blocks
    num_local_blocks: int = 2     # forced-selected local (trailing) blocks

    # --- branch toggles (full-attention fallback for short sequences) ---
    min_seq_for_sparse: int = 256  # below this, dense attention is used

    # --- implementation bundle (backends + kernel knobs) ---
    policy: KernelPolicy = dataclasses.field(default_factory=KernelPolicy)

    def __init__(self, block_size: int = 64, num_selected: int = 16,
                 cmp_block_size: int = 32, cmp_stride: int = 16,
                 window_size: int = 512, num_init_blocks: int = 1,
                 num_local_blocks: int = 2, min_seq_for_sparse: int = 256,
                 policy: KernelPolicy | None = None,
                 # policy passthroughs (tuning knobs land on self.policy)
                 q_block_size: int | None = None, interpret: bool | None = None,
                 paged_slot_block: int | None = None):
        for name, val in (("block_size", block_size),
                          ("num_selected", num_selected),
                          ("cmp_block_size", cmp_block_size),
                          ("cmp_stride", cmp_stride),
                          ("window_size", window_size),
                          ("num_init_blocks", num_init_blocks),
                          ("num_local_blocks", num_local_blocks),
                          ("min_seq_for_sparse", min_seq_for_sparse)):
            object.__setattr__(self, name, val)

        policy = policy if policy is not None else KernelPolicy()
        over = {}
        if q_block_size is not None:
            over["q_block_size"] = q_block_size
        if interpret is not None:
            over["interpret"] = interpret
        if paged_slot_block is not None:
            over["paged_slot_block"] = paged_slot_block
        if over:
            policy = dataclasses.replace(policy, **over)
        object.__setattr__(self, "policy", policy)

    # ---------------------------------------------- policy view (no warning)
    # Tuning knobs read pervasively by the kernels; kept as plain forwarding
    # properties so call sites stay `cfg.q_block_size` / `cfg.interpret`.
    @property
    def q_block_size(self) -> int:
        return self.policy.q_block_size

    @property
    def interpret(self) -> bool:
        return self.policy.interpret

    @property
    def paged_slot_block(self) -> int:
        return self.policy.paged_slot_block

    # ------------------------------------------------------------- derived
    def num_kv_blocks(self, seq_len: int) -> int:
        return max(1, (seq_len + self.block_size - 1) // self.block_size)

    def num_cmp_blocks(self, seq_len: int) -> int:
        if seq_len < self.cmp_block_size:
            return 1
        return (seq_len - self.cmp_block_size) // self.cmp_stride + 1

    def effective_T(self, seq_len: int) -> int:
        """T clamped to the number of KV blocks (short sequences)."""
        return min(self.num_selected, self.num_kv_blocks(seq_len))

    def validate(self) -> None:
        assert self.block_size % 8 == 0, "B_K must be TPU-sublane aligned"
        assert self.q_block_size % 8 == 0, "B_Q must be TPU-sublane aligned"
        assert self.cmp_block_size % self.cmp_stride == 0
        assert self.num_init_blocks >= 1 and self.num_local_blocks >= 1
