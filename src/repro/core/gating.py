"""NSA branch gating: sigmoid gates per (token, head, branch) from the layer input."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def init_gate_params(key: jax.Array, model_dim: int, num_heads: int, dtype=jnp.float32):
    scale = 1.0 / np.sqrt(model_dim)
    return {
        "w_gate": (jax.random.normal(key, (model_dim, num_heads, 3)) * scale).astype(dtype)
    }


def apply_gates(params, x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., model_dim) -> gates (..., num_heads, 3) in (0, 1)."""
    logits = jnp.einsum("...m,mhb->...hb", x.astype(jnp.float32),
                        params["w_gate"].astype(jnp.float32))
    return jax.nn.sigmoid(logits)
