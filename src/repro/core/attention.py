"""NSA attention helpers + the legacy ``nsa_attention(impl=)`` entry.

The implementation dispatch moved to the capability-based registry in
``repro.attention`` (the single public API); ``nsa_attention`` here is kept
as a thin compatibility wrapper whose ``impl`` aliases map onto registry
backend names:

  "reference" — dense-mask oracle (test scales only)
  "sparse"    — chunked gather-based pure-JAX path -> "sparse_union"
  "kernel"    — Pallas kernels for selected + sliding branches -> "fsa"
                (or whichever kernel backend ``cfg.policy.backend`` names)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import compression, gating, selection
from repro.core.nsa_config import NSAConfig
from repro.core.reference import _gqa_out, _gqa_scores, _safe_softmax


def init_nsa_params(key: jax.Array, model_dim: int, num_heads: int, head_dim: int,
                    cfg: NSAConfig, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    p = compression.init_compression_params(k1, cfg, head_dim, head_dim, dtype)
    p.update(gating.init_gate_params(k2, model_dim, num_heads, dtype))
    return p


def _cmp_and_select_chunk(params, cfg, k, v, k_cmp, v_cmp, sel_map, n, chunk):
    q_c, pos_c = chunk
    g = q_c.shape[1] // k.shape[1]
    vis = compression.cmp_visibility(pos_c, k_cmp.shape[0], cfg)
    p_cmp, _ = _safe_softmax(_gqa_scores(q_c, k_cmp), vis[:, None, :])
    out_cmp = _gqa_out(p_cmp, v_cmp).astype(q_c.dtype)
    scores = selection.importance_scores(p_cmp, sel_map, g)
    idx, valid = selection.select_blocks(scores, pos_c, cfg, n)
    return out_cmp, idx, valid


def compressed_and_selection(params, q, k, v, cfg: NSAConfig, *, q_chunk: int = 512):
    """Chunked compressed-branch output + block selection for all queries.

    q: (N, h, d) -> (out_cmp (N,h,dv), idx (N,h_k,T), valid (N,h_k,T)).
    """
    n, h, d = q.shape
    k_cmp, v_cmp = compression.compress_kv(params, k, v, cfg)
    sel_map = jnp.asarray(
        compression.cmp_to_sel_map(k_cmp.shape[0], cfg.num_kv_blocks(n), cfg)
    )
    c = min(q_chunk, n)
    pad = (c - n % c) % c
    qp = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
    pos = jnp.arange(n + pad)
    body = functools.partial(
        _cmp_and_select_chunk, params, cfg, k, v, k_cmp, v_cmp, sel_map, n
    )
    out_cmp, idx, valid = jax.lax.map(
        body, (qp.reshape(-1, c, h, d), pos.reshape(-1, c))
    )
    t = idx.shape[-1]
    return (
        out_cmp.reshape(-1, h, v.shape[-1])[:n],
        idx.reshape(-1, k.shape[1], t)[:n],
        valid.reshape(-1, k.shape[1], t)[:n],
    )


def nsa_attention(params, gates, q, k, v, cfg: NSAConfig, *, impl: str = "sparse",
                  q_chunk: int = 512):
    """NSA attention, unbatched. q: (N,h,d), k/v: (N,h_k,d), gates: (N,h,3).

    Compatibility wrapper over ``repro.attention.nsa_attention`` — ``impl``
    accepts the legacy aliases ("sparse"/"kernel"/"reference") as well as
    any registered backend name or "auto".
    """
    from repro import attention as uattn  # lazy: avoids an import cycle

    return uattn.nsa_attention(params, gates, q, k, v, cfg=cfg, mode="train",
                               backend=impl, q_chunk=q_chunk)
