"""Device-side paged-KV indexing helpers.

Low-level (no deps besides jnp) so every layer — kernels, model layers, the
serving subsystem — can address token rows through a page table without
upward imports.  A page table maps a slot's logical block index to a
physical page id; page 0 is by convention a reserved dump page (idle slots
and masked writes are routed there, keeping scatters unconditional).
"""
from __future__ import annotations

import jax.numpy as jnp


def gather_rows(pool: jnp.ndarray, table: jnp.ndarray, positions: jnp.ndarray):
    """Gather token rows through a page table.

    pool: (N_pages, P, ...); table: (max_pages,) int32; positions: (M,) token
    positions (clamped into the slot's addressable range).  Returns (M, ...).
    """
    p = pool.shape[1]
    positions = jnp.clip(positions, 0, table.shape[0] * p - 1)
    return pool[table[positions // p], positions % p]


def scatter_rows(pool: jnp.ndarray, table: jnp.ndarray, positions: jnp.ndarray,
                 values: jnp.ndarray, valid: jnp.ndarray | None = None,
                 min_pos: jnp.ndarray | None = None):
    """Scatter token rows through per-slot page tables.

    pool: (N_pages, P, ...); table: (B, max_pages); positions: (B, M);
    values: (B, M, ...).  Rows with ``valid == False`` (or positions outside
    the slot's range) are routed to dump page 0.  ``min_pos`` (B,) is a
    per-slot write floor: positions below it alias read-only shared prefix
    pages (prefix cache) and are likewise dumped.
    """
    p = pool.shape[1]
    in_range = (positions >= 0) & (positions < table.shape[1] * p)
    ok = in_range if valid is None else (valid & in_range)
    if min_pos is not None:
        ok = ok & (positions >= jnp.reshape(min_pos, (-1, 1)))
    pos_c = jnp.clip(positions, 0, table.shape[1] * p - 1)
    pages = jnp.take_along_axis(table, pos_c // p, axis=1)         # (B, M)
    pages = jnp.where(ok, pages, 0)                                # dump page
    offs = jnp.where(ok, pos_c % p, 0)
    return pool.at[pages.reshape(-1), offs.reshape(-1)].set(
        values.reshape((-1,) + values.shape[2:]).astype(pool.dtype))
