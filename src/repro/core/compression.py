"""NSA compression branch: coarse-grained block-summary tokens.

Overlapping blocks of length ``l = cfg.cmp_block_size`` at stride
``s = cfg.cmp_stride`` are summarised by a learnable map φ:
position-encoded mean pooling followed by a linear projection (shared across
KV heads).  Compressed token ``j`` summarises raw tokens ``[j*s, j*s+l)`` and
becomes causally visible to query ``t`` once fully in the past
(``j*s + l - 1 <= t``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.nsa_config import NSAConfig


def init_compression_params(key: jax.Array, cfg: NSAConfig, d_k: int,
                            d_v: int | None = None, dtype=jnp.float32):
    d_v = d_k if d_v is None else d_v
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "pe_k": (jax.random.normal(k1, (cfg.cmp_block_size, d_k)) * 0.02).astype(dtype),
        "pe_v": (jax.random.normal(k2, (cfg.cmp_block_size, d_v)) * 0.02).astype(dtype),
        "w_k": (jax.random.normal(k3, (d_k, d_k)) / np.sqrt(d_k)).astype(dtype),
        "w_v": (jax.random.normal(jax.random.fold_in(k3, 1), (d_v, d_v))
                / np.sqrt(d_v)).astype(dtype),
    }


def _pool_blocks(x: jnp.ndarray, pe: jnp.ndarray, w: jnp.ndarray, cfg: NSAConfig) -> jnp.ndarray:
    """x: (N, h_k, d) -> compressed (N_cmp, h_k, d)."""
    n = x.shape[0]
    l, s = cfg.cmp_block_size, cfg.cmp_stride
    n_cmp = cfg.num_cmp_blocks(n)
    # Gather overlapping windows: idx[j, i] = j*s + i  (clamped for short tails).
    idx = jnp.arange(n_cmp)[:, None] * s + jnp.arange(l)[None, :]
    idx = jnp.minimum(idx, n - 1)
    win = x[idx]                                   # (N_cmp, l, h_k, d)
    win = win + pe[None, :, None, :].astype(x.dtype)
    pooled = win.mean(axis=1)                      # (N_cmp, h_k, d)
    return pooled @ w.astype(x.dtype)


def compress_kv(params, k: jnp.ndarray, v: jnp.ndarray, cfg: NSAConfig):
    """k, v: (N, h_k, d) -> (k_cmp, v_cmp): (N_cmp, h_k, d)."""
    k_cmp = _pool_blocks(k, params["pe_k"], params["w_k"], cfg)
    v_cmp = _pool_blocks(v, params["pe_v"], params["w_v"], cfg)
    return k_cmp, v_cmp


def cmp_visibility(q_pos: jnp.ndarray, n_cmp: int, cfg: NSAConfig) -> jnp.ndarray:
    """(Q,) query positions -> (Q, N_cmp) bool: compressed token fully visible."""
    ends = jnp.arange(n_cmp) * cfg.cmp_stride + cfg.cmp_block_size - 1
    return q_pos[:, None] >= ends[None, :]


def cmp_to_sel_map(n_cmp: int, n_sel_blocks: int, cfg: NSAConfig) -> np.ndarray:
    """Static (N_cmp, b) overlap-weight matrix mapping compressed-token attention
    probabilities to selection-block importance scores (paper eq. for l != B_K).

    Entry (j, i) = |[j*s, j*s+l) ∩ [i*B_K, (i+1)*B_K)| / l.
    """
    s, l, bk = cfg.cmp_stride, cfg.cmp_block_size, cfg.block_size
    j = np.arange(n_cmp)[:, None]
    i = np.arange(n_sel_blocks)[None, :]
    lo = np.maximum(j * s, i * bk)
    hi = np.minimum(j * s + l, (i + 1) * bk)
    return (np.maximum(hi - lo, 0) / l).astype(np.float32)
