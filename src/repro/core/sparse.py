"""Sparse (gather-based) NSA fast path in pure JAX.

This is the production path used by model layers for training / prefill
lowering and long-context decode.  Unlike the dense-mask oracles in
``reference.py`` it never materialises an (N, N) score matrix:

* queries are processed in chunks of ``q_chunk`` (a sequential ``lax.map``),
  bounding transient memory to O(q_chunk · T · B_K · d) per KV head;
* the selected branch gathers exactly the top-T KV blocks per token;
* the sliding branch slices a (q_chunk + W - 1) window;
* the compressed branch attends to N/stride summary tokens (linear).

Total per-token cost is O(T·B_K + W + N/stride) — sub-quadratic, which is
what makes the ``long_500k`` decode shape feasible.

The Pallas kernels in ``repro.kernels`` replace the selected branch on TPU;
this module is also their semantic twin for the dry-run (XLA can cost-analyse
it, whereas a custom call is opaque).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import compression, selection
from repro.core.nsa_config import NSAConfig
from repro.core.reference import _gqa_out, _gqa_scores, _safe_softmax


def selected_gather_attention(q, k, v, idx, valid, cfg: NSAConfig, q_pos):
    """Gather-based selected attention for one query chunk.

    q: (C, h, d); k/v: (S, h_k, d); idx/valid: (C, h_k, T); q_pos: (C,).
    Returns (C, h, dv).
    """
    c, h, d = q.shape
    s, h_k, _ = k.shape
    g = h // h_k
    t = idx.shape[-1]
    bk = cfg.block_size

    tok = idx[..., None] * bk + jnp.arange(bk)              # (C, h_k, T, B_K)
    tok = tok.reshape(c, h_k, t * bk)
    tok_ok = (tok < s) & jnp.repeat(valid, bk, axis=-1) & (tok <= q_pos[:, None, None])
    tok_c = jnp.minimum(tok, s - 1).transpose(1, 0, 2)      # (h_k, C, S_sel)

    k_t = k.transpose(1, 0, 2)                              # (h_k, S, d)
    v_t = v.transpose(1, 0, 2)
    k_sel = jax.vmap(lambda kk, tt: kk[tt])(k_t, tok_c)     # (h_k, C, S_sel, d)
    v_sel = jax.vmap(lambda vv, tt: vv[tt])(v_t, tok_c)

    qg = q.reshape(c, h_k, g, d).astype(jnp.float32)
    scores = jnp.einsum("ckgd,kcsd->ckgs", qg, k_sel.astype(jnp.float32))
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    mask = tok_ok.transpose(0, 1, 2)[:, :, None, :]         # (C, h_k, 1, S_sel)
    probs, _ = _safe_softmax(scores, mask)
    out = jnp.einsum("ckgs,kcsd->ckgd", probs, v_sel.astype(jnp.float32))
    return out.reshape(c, h, -1).astype(q.dtype)


def selected_gather_chunked(q, k, v, idx, valid, cfg: NSAConfig,
                            q_chunk: int = 512):
    """Whole-sequence selected attention via :func:`selected_gather_attention`
    over ``q_chunk``-token chunks (sequential ``lax.map``).

    q: (N, h, d); k/v: (S, h_k, d); idx/valid: (N, h_k, T).  This is the
    differentiable XLA twin behind the selected-branch Pallas kernels'
    fallback VJP (``repro.attention.vjp.kernel_vjp``).
    """
    n = q.shape[0]
    c = min(q_chunk, n)
    pad = (c - n % c) % c
    pad_tok = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
    qp, idxp, validp = pad_tok(q), pad_tok(idx), pad_tok(valid)

    def body(args):
        q_c, i_c, v_c, pos_c = args
        return selected_gather_attention(q_c, k, v, i_c, v_c, cfg, pos_c)

    nc = (n + pad) // c
    out = jax.lax.map(body, (qp.reshape(nc, c, *q.shape[1:]),
                             idxp.reshape(nc, c, *idx.shape[1:]),
                             validp.reshape(nc, c, *valid.shape[1:]),
                             jnp.arange(n + pad).reshape(nc, c)))
    return out.reshape(n + pad, q.shape[1], -1)[:n]


def _union_setup(q, k, v, idx, valid, cfg: NSAConfig, q_pos):
    """Shared fwd/bwd machinery: union lists, gathers, scores, mask."""
    from repro.parallel.axes import shard as _shard

    c, h, d = q.shape
    s, h_k, _ = k.shape
    g = h // h_k
    bk = cfg.block_size
    b = (s + bk - 1) // bk
    cap = min(b, c * idx.shape[-1])          # static, always-correct bound

    oh = jnp.zeros((c, h_k, b), bool)
    oh = oh.at[jnp.arange(c)[:, None, None],
               jnp.arange(h_k)[None, :, None], idx].max(valid)
    present = oh.any(0).astype(jnp.int32)                   # (h_k, b)
    order = jnp.argsort(1 - present, axis=-1, stable=True).astype(jnp.int32)
    ids = order[:, :cap]                                    # (h_k, cap)

    tok = ids[:, :, None] * bk + jnp.arange(bk)             # (h_k, cap, B_K)
    tok_flat = jnp.minimum(tok.reshape(h_k, cap * bk), s - 1)
    k_t = _shard(k.transpose(1, 0, 2), "kv_heads", None, None)
    v_t = _shard(v.transpose(1, 0, 2), "kv_heads", None, None)
    k_sel = jax.vmap(lambda kk, tt: kk[tt])(k_t, tok_flat)
    v_sel = jax.vmap(lambda vv, tt: vv[tt])(v_t, tok_flat)
    k_sel = _shard(k_sel, "kv_heads", None, None)
    v_sel = _shard(v_sel, "kv_heads", None, None)

    qg = q.reshape(c, h_k, g, d).astype(jnp.float32)
    scores = jnp.einsum("ckgd,ksd->ckgs", qg, k_sel.astype(jnp.float32))
    scores = scores / jnp.sqrt(d).astype(jnp.float32)

    slot_blk = ids[:, :, None] * jnp.ones((1, 1, bk), jnp.int32)
    slot_blk = slot_blk.reshape(h_k, cap * bk)              # (h_k, S_u)
    picked = ((idx[:, :, None, :] == slot_blk[None, :, :, None])
              & valid[:, :, None, :]).any(-1)               # (C, h_k, S_u)
    live = (jnp.arange(cap)[None, :] <
            jnp.minimum(present.sum(-1), cap)[:, None])     # (h_k, cap)
    live = jnp.repeat(live, bk, axis=-1)
    causal = q_pos[:, None, None] >= tok_flat[None, :, :]
    in_range = (tok.reshape(h_k, cap * bk) < s)[None]
    mask = picked & live[None] & causal & in_range          # (C, h_k, S_u)

    probs, _ = _safe_softmax(scores, mask[:, :, None, :])
    return probs, mask, k_sel, v_sel, tok_flat, qg


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def selected_union_attention(q, k, v, idx, valid, cfg: NSAConfig, q_pos=None):
    """FSA-organized selected attention in XLA ops (block-batched).

    Instead of gathering T blocks per *token* (which re-fetches every block
    once per selecting token — the naive path above), gather the **union** of
    blocks selected by any token of this chunk once per (chunk, KV head) and
    mask.  This is exactly the FSA kernel's data-movement strategy, expressed
    as gather+einsum so XLA (and the dry-run cost model) see it.  Traffic per
    chunk drops from C·T·B_K·d to |union|·B_K·d ≤ min(b, C·T)·B_K·d.

    Backward is a custom VJP: dK/dV are produced by a *per-KV-head-sharded*
    scatter-add (the FSA reduction step) — without it XLA all-gathers the
    full (B,S,h_K,d) f32 cotangent buffer once per chunk (measured 4.4e12
    B/dev on codeqwen train_4k; see README "Layout" and the perf notes in
    the git history of this module).

    q: (C, h, d); k/v: (S, h_k, d); idx/valid: (C, h_k, T); q_pos: (C,).
    """
    probs, _, _, v_sel, _, _ = _union_setup(q, k, v, idx, valid, cfg, q_pos)
    c, h, d = q.shape
    out = jnp.einsum("ckgs,ksd->ckgd", probs, v_sel.astype(jnp.float32))
    return out.reshape(c, h, -1).astype(q.dtype)


def _union_fwd(q, k, v, idx, valid, cfg, q_pos):
    out = selected_union_attention(q, k, v, idx, valid, cfg, q_pos)
    return out, (q, k, v, idx, valid, q_pos)


def _union_bwd(cfg, res, dout):
    from repro.parallel.axes import shard as _shard

    q, k, v, idx, valid, q_pos = res
    c, h, d = q.shape
    s, h_k, _ = k.shape
    g = h // h_k
    dv_dim = v.shape[-1]
    # recompute (remat-style: nothing big is saved across the chunk loop)
    probs, mask, k_sel, v_sel, tok_flat, qg = _union_setup(
        q, k, v, idx, valid, cfg, q_pos)
    do = dout.reshape(c, h_k, g, dv_dim).astype(jnp.float32)

    dprobs = jnp.einsum("ckgd,ksd->ckgs", do, v_sel.astype(jnp.float32))
    dv_sel = jnp.einsum("ckgs,ckgd->ksd", probs, do)
    # softmax backward (masked rows have probs==0 so flow nothing)
    inner = jnp.sum(dprobs * probs, axis=-1, keepdims=True)
    dscores = probs * (dprobs - inner) / jnp.sqrt(d).astype(jnp.float32)
    dq = jnp.einsum("ckgs,ksd->ckgd", dscores, k_sel.astype(jnp.float32))
    dk_sel = jnp.einsum("ckgs,ckgd->ksd", dscores, qg)

    # FSA reduction: scatter the per-union-slot cotangents back to K/V rows,
    # locally per KV head (sharded over "kv_heads" — no cross-shard traffic)
    dk_sel = _shard(dk_sel, "kv_heads", None, None)
    dv_sel = _shard(dv_sel, "kv_heads", None, None)

    def scat(upd, width):
        buf = jnp.zeros((h_k, s, width), jnp.float32)
        buf = jax.vmap(lambda b_, t_, u_: b_.at[t_].add(u_))(buf, tok_flat, upd)
        return _shard(buf, "kv_heads", None, None).transpose(1, 0, 2)

    dk = scat(dk_sel, d).astype(k.dtype)
    dv = scat(dv_sel, dv_dim).astype(v.dtype)
    dq = dq.reshape(c, h, d).astype(q.dtype)
    zi = jnp.zeros(idx.shape, jax.dtypes.float0)
    zv = jnp.zeros(valid.shape, jax.dtypes.float0)
    zp = jnp.zeros(q_pos.shape, jax.dtypes.float0)
    return dq, dk, dv, zi, zv, zp


selected_union_attention.defvjp(_union_fwd, _union_bwd)


def sliding_window_chunk(q, k, v, start, cfg: NSAConfig, q_pos):
    """Sliding-window attention for one query chunk.

    start: scalar — global position of the first key to slice.  Slices
    min(S, C + W - 1) keys beginning at ``start`` (clamped by dynamic_slice).
    """
    c = q.shape[0]
    s, h_k, d = k.shape
    w = cfg.window_size
    span = min(s, c + w - 1)
    start = jnp.clip(start, 0, s - span)
    k_win = jax.lax.dynamic_slice_in_dim(k, start, span, axis=0)
    v_win = jax.lax.dynamic_slice_in_dim(v, start, span, axis=0)
    key_pos = start + jnp.arange(span)
    mask = (q_pos[:, None] >= key_pos[None, :]) & (q_pos[:, None] - key_pos[None, :] < w)
    probs, _ = _safe_softmax(_gqa_scores(q, k_win), mask[:, None, :])
    return _gqa_out(probs, v_win).astype(q.dtype)


def _nsa_chunk(params, cfg, k, v, k_cmp, v_cmp, sel_map, chunk,
               selected_fn=None):
    """Process one query chunk. chunk = (q_c, gates_c, pos_c).

    ``selected_fn(q_c, k, v, idx, valid, cfg, pos_c)`` is the selected-branch
    organization — ``selected_union_attention`` (FSA block-union, the
    production default) or ``selected_gather_attention`` (naive per-token
    gather baseline).  The ``repro.attention`` registry passes it; there is
    no string dispatch here.
    """
    q_c, gates_c, pos_c = chunk
    n = k.shape[0]
    g = q_c.shape[1] // k.shape[1]

    # --- compressed branch (+ selection scores) ---
    vis = compression.cmp_visibility(pos_c, k_cmp.shape[0], cfg)
    p_cmp, _ = _safe_softmax(_gqa_scores(q_c, k_cmp), vis[:, None, :])
    out_cmp = _gqa_out(p_cmp, v_cmp)

    # --- selection ---
    scores = selection.importance_scores(p_cmp, sel_map, g)
    idx, valid = selection.select_blocks(scores, pos_c, cfg, n)

    # --- selected branch (FSA block-union unless the caller overrides) ---
    if selected_fn is None:
        selected_fn = selected_union_attention
    out_sel = selected_fn(q_c, k, v, idx, valid, cfg, pos_c)

    # --- sliding branch ---
    out_win = sliding_window_chunk(q_c, k, v, pos_c[0] - (cfg.window_size - 1), cfg, pos_c)

    gates = gates_c.astype(jnp.float32)
    out = (
        gates[..., 0:1] * out_cmp.astype(jnp.float32)
        + gates[..., 1:2] * out_sel.astype(jnp.float32)
        + gates[..., 2:3] * out_win.astype(jnp.float32)
    )
    return out.astype(q_c.dtype), (idx, valid)


def nsa_attention_sparse(
    params,
    gates: jnp.ndarray,
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    cfg: NSAConfig,
    *,
    q_chunk: int = 512,
    return_selection: bool = False,
    selected_fn=None,
):
    """Full NSA attention, sparse path. q: (N, h, d); gates: (N, h, 3).

    ``selected_fn`` picks the selected-branch organization (see
    ``_nsa_chunk``); None means the FSA block-union production path.
    """
    n, h, d = q.shape
    k_cmp, v_cmp = compression.compress_kv(params, k, v, cfg)
    sel_map = jnp.asarray(
        compression.cmp_to_sel_map(k_cmp.shape[0], cfg.num_kv_blocks(n), cfg)
    )

    c = min(q_chunk, n)
    if n % c:  # pad to a whole number of chunks
        pad = c - n % c
        q = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
        gates = jnp.pad(gates, ((0, pad), (0, 0), (0, 0)))
    n_pad = q.shape[0]
    pos = jnp.arange(n_pad)

    body = functools.partial(_nsa_chunk, params, cfg, k, v, k_cmp, v_cmp,
                             sel_map, selected_fn=selected_fn)
    chunks = (
        q.reshape(n_pad // c, c, h, d),
        gates.reshape(n_pad // c, c, h, 3),
        pos.reshape(n_pad // c, c),
    )
    out, (idx, valid) = jax.lax.map(body, chunks)
    out = out.reshape(n_pad, h, -1)[:n]
    if return_selection:
        t = idx.shape[-1]
        return out, (idx.reshape(n_pad, -1, t)[:n], valid.reshape(n_pad, -1, t)[:n])
    return out


def decode_cmp_and_select(q_c, k_cmp, v_cmp, pos, cfg: NSAConfig,
                          seq_len: int):
    """Shared one-token decode prologue: compressed-branch attention + top-T
    block selection.  Used by both the dense-cache decode below and the
    paged decode in ``kernels.ops.paged_decode_attention_batched`` (kernel
    and gather-reference paths alike) so the paths stay provably identical.

    q_c: (1, h, d); k_cmp/v_cmp: (N_cmp, h_k, d); pos: scalar; seq_len: raw
    KV span (block ids index [0, num_kv_blocks(seq_len))).
    Returns (out_cmp (1, h, dv), idx (1, h_k, T), valid).
    """
    g = q_c.shape[1] // k_cmp.shape[1]
    # mask compressed tokens whose window is not complete or in the future
    n_cmp = k_cmp.shape[0]
    ends = jnp.arange(n_cmp) * cfg.cmp_stride + cfg.cmp_block_size - 1
    vis = (ends <= pos)[None, None, :]
    p_cmp, _ = _safe_softmax(_gqa_scores(q_c, k_cmp), vis)
    out_cmp = _gqa_out(p_cmp, v_cmp)

    sel_map = jnp.asarray(
        compression.cmp_to_sel_map(n_cmp, cfg.num_kv_blocks(seq_len), cfg))
    scores = selection.importance_scores(p_cmp, sel_map, g)
    idx, valid = selection.select_blocks(scores, pos[None], cfg, seq_len)
    return out_cmp, idx, valid


def nsa_decode_step(
    params,
    gates: jnp.ndarray,
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    k_cmp: jnp.ndarray,
    v_cmp: jnp.ndarray,
    pos: jnp.ndarray,
    cfg: NSAConfig,
):
    """One-token NSA decode. q: (h, d); caches: (S, h_k, d) / (N_cmp, h_k, d).

    ``pos`` is the absolute position of the query token; cache entries at
    positions > pos (and compressed tokens not yet complete) are masked.
    Cost: O(N_cmp + T·B_K + W) — linear in context with a small constant.
    """
    s = k_cache.shape[0]
    q_c = q[None]                                            # (1, h, d)
    pos_c = pos[None]

    out_cmp, idx, valid = decode_cmp_and_select(q_c, k_cmp, v_cmp, pos, cfg, s)
    out_sel = selected_gather_attention(q_c, k_cache, v_cache, idx, valid, cfg, pos_c)
    out_win = sliding_window_chunk(
        q_c, k_cache, v_cache, pos - (cfg.window_size - 1), cfg, pos_c
    )

    gf = gates.astype(jnp.float32)[None]
    out = (
        gf[..., 0:1] * out_cmp.astype(jnp.float32)
        + gf[..., 1:2] * out_sel.astype(jnp.float32)
        + gf[..., 2:3] * out_win.astype(jnp.float32)
    )
    return out[0].astype(q.dtype)
