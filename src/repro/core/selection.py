"""NSA top-T KV-block selection from compressed-attention scores.

Selection is shared across the ``g`` query heads of a GQA group (scores are
summed over the group, per KV head) so that one KV fetch serves the whole
group — this is what both the NSA and FSA kernels exploit.

Returned indices are ascending-sorted; invalid slots (fewer causal blocks than
``T``) are marked in a boolean mask and their index clamped into range so that
gathers stay safe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.nsa_config import NSAConfig

NEG_INF = -1e30


def importance_scores(p_cmp: jnp.ndarray, sel_map: jnp.ndarray, g: int) -> jnp.ndarray:
    """p_cmp: (Q, h, N_cmp) compressed-attention probs; sel_map: (N_cmp, b).

    Returns (Q, h_k, b) group-summed selection-block importance.
    """
    q, h, _ = p_cmp.shape
    scores = jnp.einsum("qhc,cb->qhb", p_cmp.astype(jnp.float32), sel_map)
    return scores.reshape(q, h // g, g, -1).sum(axis=2)


def select_blocks(
    scores: jnp.ndarray,
    q_pos: jnp.ndarray,
    cfg: NSAConfig,
    seq_len: int,
):
    """Top-T block selection with forced initial/local blocks and causality.

    scores: (Q, h_k, b) importance; q_pos: (Q,) absolute query positions.
    Returns (idx, valid): idx int32 (Q, h_k, T) ascending, valid bool same shape.
    """
    from repro.parallel.axes import shard as _shard

    q, h_k, b = scores.shape
    t_eff = min(cfg.num_selected, b)
    blk = jnp.arange(b)
    cur_blk = q_pos // cfg.block_size                       # (Q,)
    # keep selection math local per KV-head shard: top_k/argsort are row-wise,
    # so pinning the layout avoids XLA gathering scores per chunk
    scores = _shard(scores, None, "kv_heads", None)

    causal = blk[None, :] <= cur_blk[:, None]               # (Q, b) block start <= t
    forced_init = blk[None, :] < cfg.num_init_blocks
    # local: current block and the (num_local-1) preceding ones
    forced_local = (blk[None, :] <= cur_blk[:, None]) & (
        blk[None, :] >= cur_blk[:, None] - (cfg.num_local_blocks - 1)
    )
    forced = (forced_init | forced_local) & causal          # (Q, b)

    s = scores + jnp.where(forced[:, None, :], 1e30, 0.0)
    s = jnp.where(causal[:, None, :], s, NEG_INF)

    top_s, top_i = jax.lax.top_k(s, t_eff)                  # (Q, h_k, T)
    valid = top_s > NEG_INF / 2
    # ascending sort by index, invalid slots pushed to the end
    sort_key = jnp.where(valid, top_i, b + 1)
    order = jnp.argsort(sort_key, axis=-1)
    top_i = jnp.take_along_axis(top_i, order, axis=-1)
    valid = jnp.take_along_axis(valid, order, axis=-1)
    idx = jnp.where(valid, top_i, 0).astype(jnp.int32)      # clamp for safe gather
    idx = _shard(idx, None, "kv_heads", None)
    valid = _shard(valid, None, "kv_heads", None)
    return idx, valid
