"""FSA index tensors (the I_i / O_i machinery of the paper, block-granular).

Given the per-token selection ``idx/valid`` (N, h_K, T) these builders produce
the scalar-prefetch operands consumed by the Pallas kernels:

* ``build_qblock_union``     — per (KV head, query block): the ascending list
  of KV blocks selected by ≥1 token of that query block (the inner-loop
  schedule of the FSA-TPU kernel), padded by repeating the last valid entry so
  that clamped index maps re-touch a block already in VMEM (the TPU analogue
  of the paper's early-return).
* ``build_kvblock_qlists``   — per (KV head, KV block): the list of query
  blocks containing ≥1 token that selected it (the paper's I_i, block level),
  plus for each entry the *slot* of this KV block inside that query block's
  union list (the paper's O_i output mapping, used to address O_buf).

On TPU at production scale these builders would themselves be fused kernels;
here they are jnp (they are cheap: O(N·T) one-hots at block granularity).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.nsa_config import NSAConfig


def selection_presence(idx, valid, num_blocks: int, q_block: int):
    """-> present (h_K, n_qblks, b) bool: q-block qb has ≥1 token selecting blk."""
    n, h_k, t = idx.shape
    oh = jnp.zeros((n, h_k, num_blocks), bool)
    oh = oh.at[jnp.arange(n)[:, None, None], jnp.arange(h_k)[None, :, None], idx].max(
        valid
    )
    n_qblks = (n + q_block - 1) // q_block
    pad = n_qblks * q_block - n
    if pad:
        oh = jnp.pad(oh, ((0, pad), (0, 0), (0, 0)))
    return oh.reshape(n_qblks, q_block, h_k, num_blocks).any(1).transpose(1, 0, 2)


def _pack(present, cap: int):
    """present (..., b) -> (ids (..., cap) ascending padded-with-last, cnt (...,))."""
    b = present.shape[-1]
    order = jnp.argsort(~present, axis=-1, stable=True).astype(jnp.int32)
    cnt = present.sum(-1).astype(jnp.int32)
    ids = order[..., :cap]
    slot = jnp.minimum(jnp.arange(cap), jnp.maximum(cnt[..., None] - 1, 0))
    ids = jnp.take_along_axis(ids, slot, axis=-1)
    return ids, jnp.minimum(cnt, cap)


def build_qblock_union(idx, valid, cfg: NSAConfig, seq_len: int, cap: int | None = None):
    """-> (kv_ids (h_K, n_qblks, cap) int32, kv_cnt (h_K, n_qblks) int32)."""
    b = cfg.num_kv_blocks(seq_len)
    if cap is None:
        cap = min(b, cfg.q_block_size * idx.shape[-1])
    present = selection_presence(idx, valid, b, cfg.q_block_size)
    return _pack(present, cap)


def build_kvblock_qlists(idx, valid, cfg: NSAConfig, seq_len: int,
                         union_cap: int | None = None):
    """Paper I_i/O_i at block granularity.

    Returns (q_ids, slot_ids, q_cnt):
      q_ids   (h_K, b, n_qblks) — query blocks attending KV block i (ascending,
                                  padded with last valid);
      slot_ids(h_K, b, n_qblks) — position of KV block i in that query block's
                                  union list (O_buf slot);
      q_cnt   (h_K, b)          — number of valid entries.
    """
    b = cfg.num_kv_blocks(seq_len)
    present = selection_presence(idx, valid, b, cfg.q_block_size)  # (h_K, nq, b)
    # union slot of blk i within q-block qb = #selected blocks with id < i
    csum = jnp.cumsum(present, axis=-1)
    slot_of = jnp.where(present, csum - 1, 0).astype(jnp.int32)    # (h_K, nq, b)
    present_t = present.transpose(0, 2, 1)                         # (h_K, b, nq)
    q_ids, q_cnt = _pack(present_t, present_t.shape[-1])
    hk = jnp.arange(q_ids.shape[0])[:, None, None]
    blk = jnp.arange(b)[None, :, None]
    slot_ids = slot_of[hk, q_ids, blk]                             # (h_K, b, nq)
    if union_cap is not None:
        slot_ids = jnp.minimum(slot_ids, union_cap - 1)
    return q_ids, slot_ids.astype(jnp.int32), q_cnt
