"""repro.checkpoint — sharded, mesh-agnostic, atomic checkpointing."""
from repro.checkpoint import ckpt
