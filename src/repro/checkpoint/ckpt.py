"""Sharded, mesh-agnostic checkpointing with atomic manifests.

Design (no orbax dependency — pure numpy + JSON):
  * each leaf is saved as a .npy keyed by its tree path (mesh-agnostic:
    restore re-shards onto whatever mesh/device-count the new job has —
    this is what makes restart-after-resize *elastic*);
  * writes go to ``step_N.tmp/`` then atomically rename to ``step_N/`` and
    update ``LATEST`` — a crashed writer never corrupts the newest valid
    checkpoint;
  * optional async writer thread keeps the training loop running during
    serialization;
  * ``restore_latest`` validates the manifest (leaf count + shapes) and falls
    back to the previous step if the newest is damaged.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir, step: int, state, *, keep: int = 3) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"step_{step}.tmp"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat, _ = _flatten(state)
    manifest = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fn = key.replace("/", "__") + ".npy"
        logical = str(arr.dtype)
        if logical == "bfloat16":        # numpy can't round-trip bf16
            np.save(tmp / fn, arr.view(np.uint16))
        else:
            np.save(tmp / fn, arr)
        manifest[key] = {"file": fn, "shape": list(arr.shape),
                         "dtype": logical}
    (tmp / "manifest.json").write_text(json.dumps(
        {"step": step, "leaves": manifest}, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    (ckpt_dir / "LATEST.tmp").write_text(str(step))
    (ckpt_dir / "LATEST.tmp").rename(ckpt_dir / "LATEST")

    # retention
    steps = sorted((int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
                    if p.is_dir() and not p.name.endswith(".tmp")))
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)
    return final


def save_async(ckpt_dir, step: int, state, *, keep: int = 3) -> threading.Thread:
    """Snapshot to host memory synchronously, write to disk in a thread."""
    host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_state),
                         kwargs={"keep": keep}, daemon=True)
    t.start()
    return t


def _valid(path: pathlib.Path) -> bool:
    try:
        man = json.loads((path / "manifest.json").read_text())
        return all((path / rec["file"]).exists()
                   for rec in man["leaves"].values())
    except Exception:  # noqa: BLE001
        return False


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    candidates = sorted((int(p.name.split("_")[1])
                         for p in ckpt_dir.glob("step_*")
                         if p.is_dir() and _valid(p)), reverse=True)
    return candidates[0] if candidates else None


def restore(ckpt_dir, step: int, state_like, *, shardings=None):
    """Restore into the structure of ``state_like``; reshard if given
    ``shardings`` (a matching tree of NamedSharding) — device count may
    differ from the saving job (elastic restart)."""
    path = pathlib.Path(ckpt_dir) / f"step_{step}"
    man = json.loads((path / "manifest.json").read_text())["leaves"]
    flat, treedef = _flatten(state_like)
    shard_flat = _flatten(shardings)[0] if shardings is not None else {}

    out = {}
    for key, like in flat.items():
        rec = man[key]
        arr = np.load(path / rec["file"])
        if rec["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        assert tuple(arr.shape) == tuple(np.shape(like)), \
            f"{key}: ckpt {arr.shape} != model {np.shape(like)}"
        if key in shard_flat:
            out[key] = jax.device_put(arr, shard_flat[key])
        else:
            out[key] = jax.device_put(arr)
    leaves = [out[k] for k in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_latest(ckpt_dir, state_like, *, shardings=None):
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    return restore(ckpt_dir, step, state_like, shardings=shardings), step
