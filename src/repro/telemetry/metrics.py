"""Dependency-free process-local metrics: counters, gauges, histograms.

Instruments live in a :class:`Registry`.  Two registries matter in practice:

* the **global** registry (``telemetry.registry()``), disabled by default —
  ``enable()`` turns it (and the optional JSONL event sink) on.  Library
  code (attention dispatch counters, resolve-fallback events, span timing)
  records here, so an un-instrumented run pays only a single attribute
  check per call site (the disabled registry hands out no-op singletons);
* **private** registries owned by long-lived components (the serving
  ``Engine`` constructs one, always enabled) whose snapshots back
  user-facing accounting (``Engine.summary()``) and therefore must not
  depend on whether global telemetry is switched on.

``snapshot()`` returns a plain nested dict (JSON-ready); ``exposition()``
renders the Prometheus text format.  Events (span ends, per-tick samples,
resolution fallbacks) stream to the process-wide JSONL sink when one is
attached via ``enable(jsonl=...)``.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Optional

# span / latency histograms default to millisecond-scale exponential buckets
DEFAULT_BUCKETS_MS = (0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
                      100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0)


def _label_key(labels: dict) -> str:
    """Canonical '{k="v",...}' label string ('' for no labels); sorted so
    the same label set always maps to the same series."""
    if not labels:
        return ""
    return ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))


class Counter:
    """Monotonically increasing float counter."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-value gauge that also tracks min/max/sample-count, so peak
    tracking (page utilization) needs no caller-side max() bookkeeping."""

    __slots__ = ("name", "labels", "value", "vmin", "vmax", "samples")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self.samples = 0

    def set(self, v: float) -> None:
        v = float(v)
        self.value = v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        self.samples += 1

    def stats(self) -> dict:
        return {"last": self.value, "samples": self.samples,
                "min": self.vmin if self.samples else 0.0,
                "max": self.vmax if self.samples else 0.0}


class Histogram:
    """Cumulative-bucket histogram (Prometheus ``le`` convention)."""

    __slots__ = ("name", "labels", "bounds", "counts", "count", "sum")

    def __init__(self, name: str, labels: dict, bounds=DEFAULT_BUCKETS_MS):
        self.name = name
        self.labels = labels
        self.bounds = tuple(bounds)
        self.counts = [0] * (len(self.bounds) + 1)   # +Inf overflow bucket
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def stats(self) -> dict:
        cum, out = 0, {}
        for b, c in zip(self.bounds, self.counts):
            cum += c
            out[str(b)] = cum
        out["+Inf"] = self.count
        return {"count": self.count, "sum": self.sum, "buckets": out}


class _Noop:
    """Shared no-op instrument: every mutator is a bound no-op, so the
    disabled-telemetry cost of a call site is one attribute check plus one
    no-op call."""

    __slots__ = ()
    value = 0.0

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def stats(self) -> dict:
        return {}


NOOP = _Noop()


class JsonlSink:
    """Append-only JSONL event sink (one JSON object per line)."""

    def __init__(self, path: str):
        self.path = path
        self._fh = open(path, "a", buffering=1)
        self._lock = threading.Lock()

    def emit(self, record: dict) -> None:
        line = json.dumps(record, sort_keys=True, default=float)
        with self._lock:
            self._fh.write(line + "\n")

    def close(self) -> None:
        with self._lock:
            if not self._fh.closed:
                self._fh.close()


class Registry:
    """Process-local instrument registry.

    ``counter`` / ``gauge`` / ``histogram`` are get-or-create by
    ``(name, labels)``; when the registry is disabled they return the no-op
    singleton instead, which is the whole disabled-mode cost model.
    ``event()`` forwards a record to the process-wide JSONL sink (if one is
    attached and global telemetry is on).
    """

    def __init__(self, enabled: bool = True, name: str = ""):
        self.enabled = enabled
        self.name = name
        self._lock = threading.Lock()
        self._counters: dict = {}
        self._gauges: dict = {}
        self._histograms: dict = {}

    # ------------------------------------------------------- instruments
    def _get(self, store: dict, cls, name: str, labels: dict, **kw):
        key = (name, _label_key(labels))
        inst = store.get(key)
        if inst is None:
            with self._lock:
                inst = store.setdefault(key, cls(name, labels, **kw))
        return inst

    def counter(self, name: str, **labels) -> Counter:
        if not self.enabled:
            return NOOP
        return self._get(self._counters, Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        if not self.enabled:
            return NOOP
        return self._get(self._gauges, Gauge, name, labels)

    def histogram(self, name: str, *, buckets=DEFAULT_BUCKETS_MS,
                  **labels) -> Histogram:
        if not self.enabled:
            return NOOP
        return self._get(self._histograms, Histogram, name, labels,
                         bounds=buckets)

    # ------------------------------------------------------------ events
    def event(self, kind: str, **fields) -> None:
        """Stream one event record to the process-wide JSONL sink (no-op
        without an attached sink).  Registry enablement does not gate this:
        a private always-on registry's events still only flow when the user
        asked for a sink."""
        emit_event(kind, registry=self.name, **fields)

    # ---------------------------------------------------------- read-out
    def snapshot(self) -> dict:
        """Plain-dict view: {counters|gauges|histograms: {name: {labelkey:
        value|stats}}} — JSON-ready, stable key order left to the caller."""
        snap = {"counters": {}, "gauges": {}, "histograms": {}}
        for (name, lk), c in sorted(self._counters.items()):
            snap["counters"].setdefault(name, {})[lk] = c.value
        for (name, lk), g in sorted(self._gauges.items()):
            snap["gauges"].setdefault(name, {})[lk] = g.stats()
        for (name, lk), h in sorted(self._histograms.items()):
            snap["histograms"].setdefault(name, {})[lk] = h.stats()
        return snap

    def exposition(self) -> str:
        """Prometheus text exposition (stable, sorted — golden-testable)."""
        lines = []
        for (name, lk), c in sorted(self._counters.items()):
            lines.append(f"# TYPE {name} counter")
            lines.append(f"{name}{{{lk}}} {_fmt(c.value)}" if lk
                         else f"{name} {_fmt(c.value)}")
        for (name, lk), g in sorted(self._gauges.items()):
            lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name}{{{lk}}} {_fmt(g.value)}" if lk
                         else f"{name} {_fmt(g.value)}")
        for (name, lk), h in sorted(self._histograms.items()):
            lines.append(f"# TYPE {name} histogram")
            cum = 0
            for b, c in zip(h.bounds, h.counts):
                cum += c
                le = f'le="{b}"'
                lines.append(f"{name}_bucket{{{_join(lk, f0=le)}}} {cum}")
            le = 'le="+Inf"'
            lines.append(f"{name}_bucket{{{_join(lk, f0=le)}}} {h.count}")
            lines.append(f"{name}_sum{{{lk}}} {_fmt(h.sum)}" if lk
                         else f"{name}_sum {_fmt(h.sum)}")
            lines.append(f"{name}_count{{{lk}}} {h.count}" if lk
                         else f"{name}_count {h.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


def _fmt(v: float) -> str:
    return str(int(v)) if float(v).is_integer() else repr(float(v))


def _join(label_key: str, *, f0: str) -> str:
    return f"{label_key},{f0}" if label_key else f0


# ----------------------------------------------------- snapshot accessors
def counter_value(snap: dict, name: str, **labels) -> float:
    """Read one counter series out of a ``snapshot()`` dict (0.0 absent)."""
    return snap.get("counters", {}).get(name, {}).get(_label_key(labels), 0.0)


def gauge_stats(snap: dict, name: str, **labels) -> dict:
    return snap.get("gauges", {}).get(name, {}).get(
        _label_key(labels), {"last": 0.0, "min": 0.0, "max": 0.0,
                             "samples": 0})


# --------------------------------------------------------- global state
_GLOBAL = Registry(enabled=False, name="global")
_SINK: Optional[JsonlSink] = None


def registry() -> Registry:
    """The process-global registry (disabled until ``enable()``)."""
    return _GLOBAL


def enabled() -> bool:
    return _GLOBAL.enabled


def enable(jsonl: str | None = None) -> Registry:
    """Turn global telemetry on; optionally attach a JSONL event sink."""
    global _SINK
    _GLOBAL.enabled = True
    if jsonl is not None:
        if _SINK is not None:
            _SINK.close()
        _SINK = JsonlSink(jsonl)
    return _GLOBAL


def disable() -> None:
    """Turn global telemetry off and detach/close the JSONL sink."""
    global _SINK
    _GLOBAL.enabled = False
    if _SINK is not None:
        _SINK.close()
        _SINK = None


def sink() -> Optional[JsonlSink]:
    return _SINK


def emit_event(kind: str, **fields) -> None:
    """Write one event to the JSONL sink, if telemetry is on and a sink is
    attached.  Timestamped here so every record is self-describing."""
    if _SINK is None or not _GLOBAL.enabled:
        return
    _SINK.emit({"kind": kind, "t": time.time(), **fields})
