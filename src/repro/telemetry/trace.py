"""Context-manager spans: wall-clock (and optionally device-synced) timing
into metric registries, plus ``jax.profiler`` annotations so the same
regions show up labeled in XLA profiles.

A span records into up to two registries — an explicit one passed by the
caller (e.g. the serving engine's private always-on registry) and the
global registry when global telemetry is enabled — as a ``span_ms``
histogram keyed by the span name, and emits a ``span`` event (name,
duration, nesting depth, parent) to the JSONL sink.  When neither registry
is live the span is a no-op that never reads the clock.

``jax.named_scope`` is re-exported as :func:`named_scope` for labeling
*traced* regions (Pallas kernel launches) inside jitted code; spans
themselves wrap host-side regions with ``jax.profiler.TraceAnnotation``.
"""
from __future__ import annotations

import contextlib
import threading
import time

import jax

from repro.telemetry import metrics

_STACK = threading.local()          # per-thread span nesting stack


def _stack() -> list:
    s = getattr(_STACK, "names", None)
    if s is None:
        s = _STACK.names = []
    return s


class SpanHandle:
    """Yielded by :func:`span`: attach annotations or device-sync targets."""

    __slots__ = ("name", "labels", "fields", "_sync")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels          # histogram series key (keep bounded!)
        self.fields = {}              # event-only payload (any cardinality)
        self._sync = None

    def annotate(self, **fields) -> None:
        """Attach event-only fields known at exit (e.g. a batch count).
        These go to the JSONL event, NOT the histogram series key — so
        unbounded values never explode metric cardinality."""
        self.fields.update(fields)

    def sync(self, tree):
        """Mark ``tree`` (jax arrays / pytree) to be blocked on before the
        end timestamp — device-synced timing instead of dispatch timing.
        Returns ``tree`` so it drops into expressions."""
        self._sync = tree
        return tree


_NOOP_HANDLE = SpanHandle("", {})


def named_scope(name: str):
    """Label a *traced* region (use inside jit around kernel launches)."""
    return jax.named_scope(name)


@contextlib.contextmanager
def span(name: str, registry: metrics.Registry | None = None, **labels):
    """Time a host-side region.

    Records a ``span_ms`` histogram sample (keyed ``span=<name>`` plus any
    ``labels``) into ``registry`` (if given and enabled) and into the global
    registry (if globally enabled), emits a ``span`` JSONL event, and opens
    a ``jax.profiler.TraceAnnotation`` so profiler captures show the region
    under the same name.
    """
    targets = []
    if registry is not None and registry.enabled:
        targets.append(registry)
    g = metrics.registry()
    if g.enabled and g is not registry:
        targets.append(g)
    if not targets and metrics.sink() is None:
        yield _NOOP_HANDLE
        return

    handle = SpanHandle(name, dict(labels))
    stack = _stack()
    parent = stack[-1] if stack else None
    stack.append(name)
    t0 = time.perf_counter()
    try:
        with jax.profiler.TraceAnnotation(name):
            yield handle
    finally:
        if handle._sync is not None:
            jax.block_until_ready(handle._sync)
        dt_ms = (time.perf_counter() - t0) * 1e3
        stack.pop()
        for reg in targets:
            reg.histogram("span_ms", span=name, **handle.labels).observe(dt_ms)
        metrics.emit_event("span", name=name, ms=dt_ms, depth=len(stack),
                           parent=parent, **handle.labels, **handle.fields)
