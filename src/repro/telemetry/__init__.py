"""repro.telemetry — lightweight, dependency-free metrics + tracing.

* :mod:`repro.telemetry.metrics` — counters / gauges / bucketed histograms
  behind a process-local :class:`Registry` with ``snapshot()``, Prometheus
  text ``exposition()``, and a JSONL event sink; near-zero cost when
  disabled (the disabled registry hands out a no-op singleton).
* :mod:`repro.telemetry.trace` — context-manager :func:`span`\\ s with
  wall-clock + optional device-sync timing, emitting to the registries and
  to ``jax.profiler`` so engine tick phases and Pallas kernel regions show
  up labeled in XLA profiles.
* :mod:`repro.telemetry.pull` — :func:`serve_metrics`: a stdlib-only
  ``GET /metrics`` HTTP endpoint rendering a registry's ``exposition()``
  for real Prometheus scraping (``Engine(metrics_port=...)`` /
  ``serve_bench --metrics-port``).

Enable globally (e.g. in a bench or service entry point)::

    from repro import telemetry
    telemetry.enable(jsonl="telemetry.jsonl")     # counters + event stream
    ...
    print(telemetry.registry().exposition())      # Prometheus text format
    snap = telemetry.registry().snapshot()        # JSON-ready dict
"""
from repro.telemetry.metrics import (
    DEFAULT_BUCKETS_MS,
    Counter,
    Gauge,
    Histogram,
    JsonlSink,
    NOOP,
    Registry,
    counter_value,
    disable,
    emit_event,
    enable,
    enabled,
    gauge_stats,
    registry,
    sink,
)
from repro.telemetry.pull import MetricsServer, serve_metrics
from repro.telemetry.trace import SpanHandle, named_scope, span

__all__ = [
    "DEFAULT_BUCKETS_MS",
    "Counter",
    "Gauge",
    "Histogram",
    "JsonlSink",
    "MetricsServer",
    "NOOP",
    "Registry",
    "SpanHandle",
    "counter_value",
    "disable",
    "emit_event",
    "enable",
    "enabled",
    "gauge_stats",
    "named_scope",
    "registry",
    "serve_metrics",
    "sink",
    "span",
]
