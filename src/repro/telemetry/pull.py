"""Prometheus pull endpoint: ``GET /metrics`` over the stdlib http.server.

No dependencies — a daemon-threaded :class:`ThreadingHTTPServer` renders a
:class:`~repro.telemetry.metrics.Registry`'s ``exposition()`` (Prometheus
text format 0.0.4) on every scrape.  The registry is resolved per request,
so a server bound to the (initially disabled) global registry starts
serving real series the moment ``telemetry.enable()`` runs.

::

    handle = telemetry.serve_metrics(9090)          # global registry
    handle = telemetry.serve_metrics(0, registry=engine.telemetry)
    print(handle.url)                               # port 0 -> ephemeral
    handle.stop()

``Engine(metrics_port=...)`` / ``serve_bench --metrics-port`` expose the
engine's always-on registry this way.
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.telemetry import metrics

__all__ = ["MetricsServer", "serve_metrics"]


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-metrics/0"

    def do_GET(self):                                       # noqa: N802
        if self.path.split("?", 1)[0].rstrip("/") not in ("", "/metrics"):
            self.send_error(404, "try /metrics")
            return
        reg = self.server._registry
        body = (reg if reg is not None else metrics.registry()
                ).exposition().encode("utf-8")
        self.send_response(200)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):       # no per-scrape stderr chatter
        pass


class MetricsServer:
    """Running /metrics endpoint; ``stop()`` to shut down.

    ``port=0`` binds an ephemeral port — read it back from ``.port`` (the
    pattern tests and multi-engine processes use).
    """

    def __init__(self, port: int = 0, registry=None,
                 host: str = "127.0.0.1"):
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd._registry = registry
        self.host = host
        self.port = int(self._httpd.server_address[1])
        self.url = f"http://{host}:{self.port}/metrics"
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name=f"metrics:{self.port}",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)


def serve_metrics(port: int = 0, registry=None,
                  host: str = "127.0.0.1") -> MetricsServer:
    """Start a /metrics HTTP endpoint serving ``registry`` (default: the
    process-global registry, resolved per scrape).  Returns the running
    :class:`MetricsServer` (``.port`` / ``.url`` / ``.stop()``)."""
    return MetricsServer(port, registry=registry, host=host)
