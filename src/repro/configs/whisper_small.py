"""whisper-small [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356].

12L (decoder; + 12 encoder layers) d_model=768 12H (kv=12) d_ff=3072
vocab=51865.  input_specs provides precomputed frame embeddings (B, 1500, D)
in place of the mel conv stem.  Decoder self-attention may use NSA but
operating lengths are short; default full attention (DESIGN.md §5).
long_500k is skipped for this arch (frontend-bound audio context).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_enc_layers=12, enc_seq=1500,
    d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, mlp="gelu", attention="full",
    tie_embeddings=True,
)
