"""zamba2-7b [hybrid] — Mamba2 blocks + shared attention [arXiv:2411.15242].

81L d_model=3584 32H (GQA kv=32 => g = 1) d_ff=14336 vocab=32000,
ssm_state=64.  One *shared* attention block (single weight set) is applied
after every `shared_attn_period` Mamba2 blocks, zamba-style (the per-
invocation LoRA deltas of the real model are omitted).  NSA applies to the
shared attention blocks; Mamba2 blocks are attention-free.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, mlp="swiglu", attention="nsa",
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=112, chunk=128),
    shared_attn_period=6,
)
