"""Model configuration schema + input-shape definitions (assigned cells)."""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.nsa_config import NSAConfig


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 64
    num_shared: int = 0
    top_k: int = 8
    d_expert: int = 1024
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 0            # 0 = full-rank q projection
    rope_dim: int = 64
    nope_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 64
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "lm"               # lm | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 1024
    vocab: int = 1024
    head_dim: int = 0                # 0 -> d_model // n_heads
    mlp: str = "swiglu"              # swiglu | relu2 | gelu
    attention: str = "nsa"           # nsa | full | swa
    swa_window: int = 4096
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    use_qkv_bias: bool = False
    logit_softcap: float = 0.0

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_period: int = 6      # hybrid: shared attn every N mamba blocks

    # encdec / vlm frontends (stubs provide precomputed embeddings)
    n_enc_layers: int = 0
    enc_seq: int = 1500
    n_img_tokens: int = 256

    nsa: NSAConfig = dataclasses.field(default_factory=NSAConfig)
    # train/prefill attention backend: "auto" or any repro.attention registry
    # name; legacy aliases "sparse" (-> sparse_union) and "kernel" (-> the
    # Pallas kernel named by nsa.policy.backend, default fsa) still resolve
    attn_impl: str = "sparse"
    q_chunk: int = 512               # sparse-path chunk size (perf knob)

    remat: bool = True
    scan_layers: bool = True
    dtype: str = "bfloat16"          # activation/param dtype for dry-run

    vocab_pad_to: int = 256          # pad vocab so logits shard over "model"

    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def g(self) -> int:
        return self.n_heads // self.n_kv_heads

    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab + p - 1) // p) * p


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=128,
        vocab=256,
        head_dim=16,
        nsa=NSAConfig(block_size=16, num_selected=4, cmp_block_size=8,
                      cmp_stride=4, window_size=32, q_block_size=16,
                      min_seq_for_sparse=1),
        q_chunk=64,
        scan_layers=cfg.scan_layers,
        dtype="float32",
    )
    if cfg.moe is not None:
        base["moe"] = MoEConfig(num_experts=4, num_shared=cfg.moe.num_shared,
                                top_k=2, d_expert=32)
    if cfg.mla is not None:
        base["mla"] = MLAConfig(kv_lora=32, rope_dim=8, nope_dim=16)
    if cfg.ssm is not None:
        base["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                                chunk=16)
    if cfg.family in ("encdec",):
        base["n_enc_layers"] = 2
        base["enc_seq"] = 32
    if cfg.family == "vlm":
        base["n_img_tokens"] = 8
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
