"""h2o-danube-3-4b [dense] — llama+mistral mix, SWA [arXiv:2401.16818].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.  head_dim = 120
(deliberately not 128-aligned — exercises kernel raggedness).  The arch's
sliding-window design maps onto NSA's sliding branch (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="lm",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000, mlp="swiglu", attention="nsa",
    swa_window=4096,
)
