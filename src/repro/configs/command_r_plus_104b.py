"""command-r-plus-104b [dense] — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.  g = 12; the FSDP +
TP showcase config (largest assigned model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="lm",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab=256000, mlp="swiglu", attention="nsa",
)
