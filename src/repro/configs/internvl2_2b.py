"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The ViT frontend is a
stub per the assignment: input_specs provides precomputed patch embeddings.
NSA applies fully to the LM backbone (g = 2).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553, mlp="swiglu", attention="nsa",
    n_img_tokens=256,
)
