"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768 (attention-free) vocab=50280, ssm_state=128.
NSA is inapplicable (no attention to sparsify) — implemented without the
technique per the assignment; see DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=24, n_kv_heads=24,
    d_ff=0, vocab=50280, attention="full",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128),
    tie_embeddings=True,
)
