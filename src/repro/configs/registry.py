"""Architecture registry: --arch <id> lookup for launchers and benchmarks."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCHS: dict[str, str] = {
    "internvl2-2b": "repro.configs.internvl2_2b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "codeqwen1.5-7b": "repro.configs.codeqwen15_7b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube3_4b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "whisper-small": "repro.configs.whisper_small",
    "zamba2-7b": "repro.configs.zamba2_7b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[arch]).CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
