"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, shared+routed top-6 [arXiv:2405.04434].

27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400.  The assignment line
reads both "MoE 64e top-6" and "2 shared+160 routed"; 160 routed is the 236B
DeepSeek-V2 figure — we follow the 64-routed reading (+2 shared, top-6),
matching the real V2-Lite (see DESIGN.md §5).

MLA is implemented in absorbed (latent-space) form and NSA runs on the latent
KV — mathematically identical to materialising the 16 KV heads, and the
correct decode-time cache layout (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, mlp="swiglu", attention="nsa",
    moe=MoEConfig(num_experts=64, num_shared=2, top_k=6, d_expert=1408),
    mla=MLAConfig(kv_lora=512, rope_dim=64, nope_dim=128),
)
