"""codeqwen1.5-7b [dense] — qwen1.5 arch (qkv bias) [hf:Qwen/CodeQwen1.5-7B].

32L d_model=4096 32H (GQA kv=32 => MHA, g = 1) d_ff=13440 vocab=92416.
g = 1 is FSA's best case (the paper's 3.5x point): the vanilla NSA kernel
pads 1 query head to the hardware minimum.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="lm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab=92416, mlp="swiglu", attention="nsa",
    use_qkv_bias=True,
)
