"""repro.configs — assigned architecture configs + shape definitions."""
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, reduced
from repro.configs.registry import ARCHS, all_configs, get_config
