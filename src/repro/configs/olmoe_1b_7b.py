"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (GQA kv=16 => MHA, g = 1) d_ff=1024 (per expert)
vocab=50304, MoE 64e top-8.  Expert-parallel over the model axis.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304, mlp="swiglu", attention="nsa",
    moe=MoEConfig(num_experts=64, num_shared=0, top_k=8, d_expert=1024),
)
