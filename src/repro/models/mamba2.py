"""Mamba2 block (State Space Duality), attention-free sequence mixer.

Training/prefill uses the chunked SSD algorithm (quadratic only within a
chunk, linear across chunks via a state-passing scan); decode is the O(1)
recurrent update.  The paper's NSA technique is inapplicable here (no
attention); see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm
from repro.parallel.axes import shard


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim


def init_mamba(key, cfg) -> dict:
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads
    return {
        "w_in": dense_init(ks[0], (cfg.d_model, d_in_proj), dtype),
        "conv_w": dense_init(ks[1], (s.d_conv, conv_dim), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": jnp.zeros((d_inner,), dtype),
        "w_out": dense_init(ks[2], (d_inner, cfg.d_model), dtype),
    }


def _split_proj(zxbcdt, cfg):
    s = cfg.ssm
    d_inner, n_heads, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z, xc, bc, cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + gn, 2 * d_inner + 2 * gn],
        axis=-1)
    return z, xc, bc, cc, dt


def _causal_conv(u, w, b, conv_state=None):
    """Depthwise causal conv. u: (B,L,C), w: (K,C). conv_state: (B,K-1,C)."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    else:
        pad = conv_state.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out + b), up[:, -(k - 1):]


def ssd_chunked(x, dt, a, b_, c_, chunk: int):
    """SSD scan. x: (B,L,H,P); dt: (B,L,H); a: (H,) (negative);
    b_/c_: (B,L,H,N).  Returns (y, final_state (B,H,P,N))."""
    bsz, l, h, p = x.shape
    n = b_.shape[-1]
    q = min(chunk, l)
    pad = (q - l % q) % q
    if pad:
        x, dt = (jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
                 for t in (x, dt))
        b_ = jnp.pad(b_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_ = jnp.pad(c_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (l + pad) // q

    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    bcc = b_.reshape(bsz, nc, q, h, n).astype(jnp.float32)
    ccc = c_.reshape(bsz, nc, q, h, n).astype(jnp.float32)

    da = dtc * a                                              # (B,nc,Q,H)
    da_cs = jnp.cumsum(da, axis=2)
    # --- intra-chunk (masked quadratic) ---
    seg = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]   # (B,nc,Qi,Qj,H)
    iq = jnp.arange(q)
    causal = iq[:, None] >= iq[None, :]
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", ccc, bcc)
    w = scores * decay * dtc[:, :, None, :, :]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", w,
                        xc.astype(jnp.float32))

    # --- chunk states + inter-chunk recurrence ---
    tail = da_cs[:, :, -1:, :] - da_cs                        # (B,nc,Q,H)
    states = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn",
                        jnp.exp(tail) * dtc, bcc, xc.astype(jnp.float32))
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])                 # (B,nc,H)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry                                     # emit state BEFORE chunk

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (B,nc,H,P,N)

    y_off = jnp.einsum("bcihn,bchpn,bcih->bcihp", ccc, prev_states,
                       jnp.exp(da_cs))
    y = (y_diag + y_off).reshape(bsz, nc * q, h, p)[:, :l]
    return y.astype(x.dtype), final


def mamba_forward(p, x, cfg, conv_state=None, ssm_state=None):
    """Full-sequence Mamba2. x: (B,L,D) -> (y, (conv_state, ssm_state))."""
    s = cfg.ssm
    d_inner, n_heads, _ = _dims(cfg)
    z, xc, bc, cc, dt = _split_proj(x @ p["w_in"], cfg)
    conv_in = jnp.concatenate([xc, bc, cc], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                        conv_state)
    xc, bc, cc = jnp.split(conv_out, [d_inner, d_inner + s.n_groups * s.d_state],
                           axis=-1)
    bsz, l = x.shape[:2]
    h_per_g = n_heads // s.n_groups
    xh = xc.reshape(bsz, l, n_heads, s.head_dim)
    xh = shard(xh, "batch", "seq", "heads")
    bh = jnp.repeat(bc.reshape(bsz, l, s.n_groups, s.d_state), h_per_g, axis=2)
    ch = jnp.repeat(cc.reshape(bsz, l, s.n_groups, s.d_state), h_per_g, axis=2)
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])

    y, final = ssd_chunked(xh, dt_sp, a, bh, ch, s.chunk)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(bsz, l, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"], cfg.norm_eps)
    return y @ p["w_out"], (conv_state, final)


def mamba_decode_step(p, x_t, conv_state, ssm_state, cfg):
    """One-token recurrent update. x_t: (B,D); ssm_state: (B,H,P,N)."""
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    z, xc, bc, cc, dt = _split_proj(x_t @ p["w_in"], cfg)
    conv_in = jnp.concatenate([xc, bc, cc], axis=-1)[:, None, :]   # (B,1,C)
    window = jnp.concatenate([conv_state.astype(conv_in.dtype), conv_in], axis=1)
    conv_out = jax.nn.silu(
        (window * p["conv_w"][None]).sum(1) + p["conv_b"])         # (B,C)
    conv_state = window[:, 1:]
    xc, bc, cc = jnp.split(conv_out, [d_inner, d_inner + s.n_groups * s.d_state],
                           axis=-1)
    bsz = x_t.shape[0]
    h_per_g = n_heads // s.n_groups
    xh = xc.reshape(bsz, n_heads, s.head_dim).astype(jnp.float32)
    bh = jnp.repeat(bc.reshape(bsz, s.n_groups, s.d_state), h_per_g, axis=1)
    ch = jnp.repeat(cc.reshape(bsz, s.n_groups, s.d_state), h_per_g, axis=1)
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt_sp * a)                                      # (B,H)
    ssm_state = (ssm_state * decay[:, :, None, None]
                 + jnp.einsum("bh,bhn,bhp->bhpn", dt_sp, bh.astype(jnp.float32), xh))
    y = jnp.einsum("bhn,bhpn->bhp", ch.astype(jnp.float32), ssm_state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(bsz, d_inner).astype(x_t.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"], cfg.norm_eps)
    return y @ p["w_out"], conv_state, ssm_state


def init_mamba_cache(cfg, batch: int):
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), jnp.dtype(cfg.dtype)),
        "ssm": jnp.zeros((batch, n_heads, s.head_dim, s.d_state), jnp.float32),
    }
