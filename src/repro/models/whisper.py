"""Whisper-style encoder-decoder (audio backbone; conv frontend is a stub).

Per the assignment, the modality frontend is stubbed: ``input_specs`` provides
precomputed frame embeddings (B, enc_seq, D) in place of the mel-spectrogram
conv stem.  Encoder: bidirectional full attention.  Decoder: causal
self-attention (NSA-selectable) + cross-attention + GELU MLP, pre-LayerNorm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention_layer as attn
from repro.models.layers import (apply_mlp, cross_entropy, dense_init,
                                 init_mlp, layer_norm)
from repro.parallel.axes import shard


def _init_ln(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def _ln(p, x, eps):
    return layer_norm(x, p["scale"], p["bias"], eps)


def init_enc_block(key, cfg):
    ks = jax.random.split(key, 2)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "ln1": _init_ln(cfg.d_model, dtype),
        "ln2": _init_ln(cfg.d_model, dtype),
        "attn": attn.init_attention(ks[0], cfg),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def init_dec_block(key, cfg):
    ks = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "ln1": _init_ln(cfg.d_model, dtype),
        "ln2": _init_ln(cfg.d_model, dtype),
        "ln3": _init_ln(cfg.d_model, dtype),
        "attn": attn.init_attention(ks[0], cfg),
        "xattn": attn.init_attention(ks[1], cfg),
        "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def init_whisper(key, cfg, max_dec_len: int = 0):
    ks = jax.random.split(key, 6)
    dtype = jnp.dtype(cfg.dtype)

    def stack(fn, k, n):
        return jax.vmap(fn)(jax.random.split(k, n))

    return {
        "embed": dense_init(ks[0], (cfg.padded_vocab(), cfg.d_model), dtype, scale=0.02),
        "pos_enc": dense_init(ks[1], (cfg.enc_seq, cfg.d_model), dtype, scale=0.02),
        "enc": stack(lambda k: init_enc_block(k, cfg), ks[2], cfg.n_enc_layers),
        "enc_ln": _init_ln(cfg.d_model, dtype),
        "dec": stack(lambda k: init_dec_block(k, cfg), ks[3], cfg.n_layers),
        "dec_ln": _init_ln(cfg.d_model, dtype),
    }


def _apply_enc_block(p, x, cfg):
    h = _ln(p["ln1"], x, cfg.norm_eps)
    x = x + attn.attention_forward(p["attn"], h, cfg, causal=False)
    h = _ln(p["ln2"], x, cfg.norm_eps)
    return x + apply_mlp(p["mlp"], h, "gelu")


def _apply_dec_block(p, x, enc_out, cfg):
    h = _ln(p["ln1"], x, cfg.norm_eps)
    x = x + attn.attention_forward(p["attn"], h, cfg)
    h = _ln(p["ln2"], x, cfg.norm_eps)
    x = x + attn.cross_attention_forward(p["xattn"], h, enc_out, cfg)
    h = _ln(p["ln3"], x, cfg.norm_eps)
    return x + apply_mlp(p["mlp"], h, "gelu")


def encode(params, frames, cfg):
    """frames: (B, enc_seq, D) stub embeddings -> encoder states."""
    x = frames + params["pos_enc"][None, :frames.shape[1]].astype(frames.dtype)
    x = shard(x, "batch", "seq_sp", "embed")
    body = lambda x, p: (_apply_enc_block(p, x, cfg), None)
    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return _ln(params["enc_ln"], x, cfg.norm_eps)


def whisper_loss(params, batch, cfg):
    """batch: frames (B,enc_seq,D), tokens (B,S), labels (B,S)."""
    enc_out = encode(params, batch["frames"].astype(jnp.dtype(cfg.dtype)), cfg)
    x = params["embed"][batch["tokens"]]
    x = shard(x, "batch", "seq_sp", "embed")
    body = lambda x, p: (_apply_dec_block(p, x, enc_out, cfg), None)
    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = _ln(params["dec_ln"], x, cfg.norm_eps)
    logits = x @ params["embed"].T          # tied head (as in Whisper)
    logits = shard(logits, "batch", "seq", "vocab")
    if cfg.padded_vocab() != cfg.vocab:
        logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab,
                           logits, -1e30)
    loss, cnt = cross_entropy(logits, batch["labels"])
    return loss, {"ce": loss, "tokens": cnt}


# -------------------------------------------------------------------- decode
def init_whisper_cache(cfg, batch: int, max_len: int):
    hk, hd = cfg.n_kv_heads, cfg.hd()
    dtype = jnp.dtype(cfg.dtype)
    zeros = lambda *s: jnp.zeros(s, dtype)
    self_c = attn.init_attn_cache(cfg, batch, max_len)
    return {
        "self": jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), self_c),
        "cross_k": zeros(cfg.n_layers, batch, cfg.enc_seq, hk, hd),
        "cross_v": zeros(cfg.n_layers, batch, cfg.enc_seq, hk, hd),
    }


def whisper_prefill(params, cache, batch, cfg):
    """Encode audio, cache cross-attention K/V, prefill decoder self-attn."""
    enc_out = encode(params, batch["frames"].astype(jnp.dtype(cfg.dtype)), cfg)
    b = enc_out.shape[0]
    hk, hd = cfg.n_kv_heads, cfg.hd()

    def layer(carry, args):
        x, = carry
        p, c_self = args
        h = _ln(p["ln1"], x, cfg.norm_eps)
        h, c_self = attn.attention_prefill(p["attn"], h, cfg, c_self)
        x = x + h
        h = _ln(p["ln2"], x, cfg.norm_eps)
        ck = (enc_out @ p["xattn"]["w_k"]).reshape(b, -1, hk, hd)
        cv = (enc_out @ p["xattn"]["w_v"]).reshape(b, -1, hk, hd)
        x = x + attn.cross_attention_forward(p["xattn"], h, enc_out, cfg)
        h = _ln(p["ln3"], x, cfg.norm_eps)
        x = x + apply_mlp(p["mlp"], h, "gelu")
        return (x,), (c_self, ck, cv)

    x = params["embed"][batch["tokens"]]
    (x,), (c_self, ck, cv) = jax.lax.scan(layer, (x,), (params["dec"],
                                                        cache["self"]))
    cache = {"self": c_self, "cross_k": ck, "cross_v": cv}
    x = _ln(params["dec_ln"], x[:, -1], cfg.norm_eps)
    logits = x @ params["embed"].T
    return jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab, logits, -1e30), cache


def whisper_decode_step(params, cache, tokens, pos, cfg):
    """tokens: (B,) -> (logits, cache)."""
    from repro.core.reference import _gqa_out, _gqa_scores, _safe_softmax

    x = params["embed"][tokens]

    def layer(x, args):
        p, c_self, ck, cv = args
        h = _ln(p["ln1"], x, cfg.norm_eps)
        h, c_self = attn.attention_decode(p["attn"], h, c_self, pos, cfg)
        x = x + h
        h = _ln(p["ln2"], x, cfg.norm_eps)
        hq = (h @ p["xattn"]["w_q"]).reshape(x.shape[0], 1, cfg.n_heads, cfg.hd())

        def xa(q1, k1, v1):
            probs, _ = _safe_softmax(_gqa_scores(q1, k1),
                                     jnp.ones((1, 1, k1.shape[0]), bool))
            return _gqa_out(probs, v1)

        o = jax.vmap(xa)(hq, ck, cv).reshape(x.shape[0], -1)
        x = x + (o @ p["xattn"]["w_o"]).astype(x.dtype)
        h = _ln(p["ln3"], x, cfg.norm_eps)
        x = x + apply_mlp(p["mlp"], h, "gelu")
        return x, c_self

    x, c_self = jax.lax.scan(layer, x, (params["dec"], cache["self"],
                                        cache["cross_k"], cache["cross_v"]))
    cache = dict(cache, self=c_self)
    x = _ln(params["dec_ln"], x, cfg.norm_eps)
    logits = x @ params["embed"].T
    return jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab, logits, -1e30), cache
