"""Uniform model API over all families + ShapeDtypeStruct input specs.

``build(cfg)`` returns a Model namespace:
    init(key)                      -> params
    loss(params, batch)            -> (scalar, metrics)     [train]
    prefill(params, cache, batch)  -> (last logits, cache)  [serving]
    decode_step(params, cache, tokens, pos) -> (logits, cache)
    init_cache(batch, max_len)     -> cache

``input_specs(cfg, shape)`` returns the ShapeDtypeStruct stand-ins used by
the multi-pod dry-run (weak-type-correct, no allocation); modality frontends
are stubs — precomputed frame/patch embeddings per the assignment.
"""
from __future__ import annotations

from types import SimpleNamespace

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer, whisper


def build(cfg: ModelConfig) -> SimpleNamespace:
    if cfg.family == "encdec":
        return SimpleNamespace(
            cfg=cfg,
            init=lambda key: whisper.init_whisper(key, cfg),
            loss=lambda p, b: whisper.whisper_loss(p, b, cfg),
            logits=lambda p, b: None,
            prefill=lambda p, c, b: whisper.whisper_prefill(p, c, b, cfg),
            decode_step=lambda p, c, t, pos: whisper.whisper_decode_step(
                p, c, t, pos, cfg),
            init_cache=lambda batch, max_len: whisper.init_whisper_cache(
                cfg, batch, max_len),
        )
    return SimpleNamespace(
        cfg=cfg,
        init=lambda key: transformer.init_lm(key, cfg),
        loss=lambda p, b: transformer.lm_loss(p, b, cfg),
        logits=lambda p, b: transformer.lm_logits(p, b, cfg),
        prefill=lambda p, c, b: transformer.lm_prefill(p, c, b, cfg),
        decode_step=lambda p, c, t, pos: transformer.lm_decode_step(
            p, c, t, pos, cfg),
        init_cache=lambda batch, max_len: transformer.init_lm_cache(
            cfg, batch, max_len),
        # paged serving entries (attention families; see repro.serving)
        init_paged_cache=lambda num_pages, num_cmp_pages:
            transformer.init_lm_paged_cache(cfg, num_pages, num_cmp_pages),
        paged_prefill_chunk=lambda p, c, t, t0, ln, tb:
            transformer.lm_paged_prefill_chunk(p, c, t, t0, ln, tb, cfg),
        paged_decode_step=lambda p, c, t, pos, tb:
            transformer.lm_paged_decode_step(p, c, t, pos, tb, cfg),
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                per_device_batch: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b = per_device_batch or shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)

    if shape.mode in ("train", "prefill"):
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.family == "vlm":
            batch["img_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_img_tokens, cfg.d_model), act)
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), act)
        return batch
    # decode: one new token against a seq_len-deep KV cache
    return {
        "tokens": jax.ShapeDtypeStruct((b,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                per_device_batch: int | None = None):
    """ShapeDtypeStructs for the decode cache at this shape."""
    b = per_device_batch or shape.global_batch
    model = build(cfg)
    return jax.eval_shape(lambda: model.init_cache(b, shape.seq_len))


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Cell applicability (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k":
        if cfg.family == "encdec":
            return False, "whisper: audio context bound by conv-frontend stub"
        if cfg.attention == "full" and cfg.family not in ("ssm", "hybrid"):
            return False, "pure full-attention arch: quadratic at 500k"
    return True, ""


def make_reduced_batch(cfg: ModelConfig, key, batch: int, seq: int) -> dict:
    """Concrete random batch for CPU smoke tests."""
    ks = jax.random.split(key, 3)
    toks = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab)
    labels = jnp.concatenate(
        [toks[:, 1:], jnp.full((batch, 1), -100, toks.dtype)], axis=1)
    out = {"tokens": toks, "labels": labels}
    if cfg.family == "vlm":
        out["img_embeds"] = jax.random.normal(
            ks[1], (batch, cfg.n_img_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            ks[2], (batch, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    return out
