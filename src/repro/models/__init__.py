"""repro.models — model zoo substrate (functional param-dict modules)."""
from repro.models.registry import build, cache_specs, input_specs, supports_shape
