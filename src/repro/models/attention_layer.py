"""Attention layer: GQA / MLA projections around the NSA-FSA core.

Attention kinds (cfg.attention): "nsa" (paper technique), "full", "swa".

MLA (DeepSeek-V2) is implemented in *absorbed* form: attention runs in the
512-d latent space with a single shared KV head (q/k = latent ⊕ decoupled
RoPE part, v = latent), and the per-head value up-projection W_uv is applied
to the attention output.  This is mathematically identical to materialising
the 16 KV heads (associativity of the matmuls) and lets NSA's compression /
selection / sliding machinery — and the FSA kernels — operate on the latent
cache directly, which is also the correct decode-time layout.  (See the
model-zoo applicability notes in README "Layout" / ROADMAP.md.)

All attention math dispatches through ``repro.attention.nsa_attention``
(the capability-based backend registry); this layer only does projections,
caches and sharding.

Decode keeps a raw KV cache plus incrementally-updated NSA compression
caches, so per-token cost stays O(N/stride + T·B_K + W).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import attention as uattn
from repro.core.paging import gather_rows, scatter_rows
from repro.core import compression, gating, sparse
from repro.models.layers import apply_rope, dense_init, rms_norm
from repro.parallel.axes import shard


# ------------------------------------------------------------------ params
def init_attention(key, cfg) -> dict:
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    p = {}
    if cfg.mla is not None:
        m = cfg.mla
        dk_lat = m.kv_lora + m.rope_dim
        p["w_q"] = dense_init(ks[0], (d, h * (m.nope_dim + m.rope_dim)), dtype)
        p["w_dkv"] = dense_init(ks[1], (d, m.kv_lora), dtype)
        p["kv_norm"] = jnp.zeros((m.kv_lora,), dtype)
        p["w_kr"] = dense_init(ks[2], (d, m.rope_dim), dtype)
        # absorbed projections: q->latent (per head), latent->value head
        p["w_uk"] = dense_init(ks[3], (h, m.nope_dim, m.kv_lora), dtype)
        p["w_uv"] = dense_init(ks[4], (h, m.kv_lora, hd), dtype)
        p["w_o"] = dense_init(ks[5], (h * hd, d), dtype)
        attn_dk, attn_dv, attn_hk = dk_lat, m.kv_lora, 1
    else:
        p["w_q"] = dense_init(ks[0], (d, h * hd), dtype)
        p["w_k"] = dense_init(ks[1], (d, hk * hd), dtype)
        p["w_v"] = dense_init(ks[2], (d, hk * hd), dtype)
        p["w_o"] = dense_init(ks[3], (h * hd, d), dtype)
        if cfg.use_qkv_bias:
            p["b_q"] = jnp.zeros((h * hd,), dtype)
            p["b_k"] = jnp.zeros((hk * hd,), dtype)
            p["b_v"] = jnp.zeros((hk * hd,), dtype)
        attn_dk, attn_dv, attn_hk = hd, hd, hk
    if cfg.attention == "nsa":
        p["nsa"] = {
            **compression.init_compression_params(ks[6], cfg.nsa, attn_dk,
                                                  attn_dv, dtype),
            **gating.init_gate_params(ks[7], d, h, dtype),
        }
    del attn_hk
    return p


# -------------------------------------------------------------- projections
def _qkv(p, x, cfg, pos):
    """x: (B,S,D) -> q (B,S,h,dk), k (B,S,h_k,dk), v (B,S,h_k,dv)."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd()
    if cfg.mla is not None:
        m = cfg.mla
        c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)   # (B,S,L)
        k_rope = apply_rope(
            (x @ p["w_kr"])[:, :, None, :], pos, cfg.rope_theta)      # (B,S,1,r)
        q = (x @ p["w_q"]).reshape(b, s, h, m.nope_dim + m.rope_dim)
        q_nope, q_rope = q[..., :m.nope_dim], q[..., m.nope_dim:]
        q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
        q_lat = jnp.einsum("bshn,hnl->bshl", q_nope, p["w_uk"])       # absorbed
        q_full = jnp.concatenate([q_lat, q_rope], axis=-1)            # (B,S,h,L+r)
        k_full = jnp.concatenate([c_kv[:, :, None, :], k_rope], axis=-1)
        return q_full, k_full, c_kv[:, :, None, :]
    hk = cfg.n_kv_heads
    q = x @ p["w_q"] + (p.get("b_q", 0))
    k = x @ p["w_k"] + (p.get("b_k", 0))
    v = x @ p["w_v"] + (p.get("b_v", 0))
    q = apply_rope(q.reshape(b, s, h, hd), pos, cfg.rope_theta)
    k = apply_rope(k.reshape(b, s, hk, hd), pos, cfg.rope_theta)
    return q, k, v.reshape(b, s, hk, hd)


def _out_proj(p, o, cfg):
    """o: (B,S,h,dv_attn) -> (B,S,D)."""
    b, s = o.shape[:2]
    if cfg.mla is not None:
        o = jnp.einsum("bshl,hld->bshd", o, p["w_uv"])
    return o.reshape(b, s, -1) @ p["w_o"]


# ------------------------------------------------------------ full-sequence
def attention_forward(p, x, cfg, *, causal: bool = True):
    """Training / prefill attention over a full sequence. x: (B,S,D)."""
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _qkv(p, x, cfg, pos)
    q = shard(q, "batch", "seq", "heads")
    k = shard(k, "batch", "seq", "kv_heads")
    v = shard(v, "batch", "seq", "kv_heads")

    if cfg.attention == "nsa" and causal:
        gates = gating.apply_gates(p["nsa"], x)
        fn = lambda q1, k1, v1, g1: uattn.nsa_attention(
            p["nsa"], g1, q1, k1, v1, cfg=cfg.nsa, mode="train",
            backend=cfg.attn_impl, q_chunk=cfg.q_chunk)
        o = jax.vmap(fn)(q, k, v, gates)
    elif cfg.attention == "swa" and causal:
        fn = lambda q1, k1, v1: uattn.nsa_attention(
            None, None, q1, k1, v1, cfg=cfg.nsa, mode="train",
            algorithm="sliding", window=cfg.swa_window, q_chunk=cfg.q_chunk)
        o = jax.vmap(fn)(q, k, v)
    else:
        fn = lambda q1, k1, v1: uattn.nsa_attention(
            None, None, q1, k1, v1, cfg=cfg.nsa, mode="train",
            algorithm="full", causal=causal, q_chunk=cfg.q_chunk)
        o = jax.vmap(fn)(q, k, v)
    o = shard(o, "batch", "seq", "heads")
    return _out_proj(p, o, cfg)


def cross_attention_forward(p, x, kv_x, cfg):
    """Encoder-decoder cross attention (full, non-causal). kv_x: (B,Senc,D)."""
    b, s, _ = x.shape
    pos = jnp.zeros((b, s), jnp.int32)      # no rope on cross attention
    h, hd, hk = cfg.n_heads, cfg.hd(), cfg.n_kv_heads
    q = (x @ p["w_q"]).reshape(b, s, h, hd)
    k = (kv_x @ p["w_k"]).reshape(b, kv_x.shape[1], hk, hd)
    v = (kv_x @ p["w_v"]).reshape(b, kv_x.shape[1], hk, hd)
    o = jax.vmap(lambda a, b_, c: uattn.nsa_attention(
        None, None, a, b_, c, cfg=cfg.nsa, mode="prefill", algorithm="full",
        causal=False, q_chunk=cfg.q_chunk))(q, k, v)
    return o.reshape(b, s, -1) @ p["w_o"]


# ------------------------------------------------------------------ decode
def init_attn_cache(cfg, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    if cfg.mla is not None:
        dk = cfg.mla.kv_lora + cfg.mla.rope_dim
        dv, hk = cfg.mla.kv_lora, 1
    else:
        dk = dv = cfg.hd()
        hk = cfg.n_kv_heads
    cache = {
        "k": jnp.zeros((batch, max_len, hk, dk), dtype),
        "v": jnp.zeros((batch, max_len, hk, dv), dtype),
    }
    if cfg.attention == "nsa":
        n_cmp = cfg.nsa.num_cmp_blocks(max_len)
        cache["cmp_k"] = jnp.zeros((batch, n_cmp, hk, dk), dtype)
        cache["cmp_v"] = jnp.zeros((batch, n_cmp, hk, dv), dtype)
    return cache


def attention_prefill(p, x, cfg, cache):
    """Run full-seq attention and populate the decode cache. x: (B,S,D)."""
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    _, k, v = _qkv(p, x, cfg, pos)
    y = attention_forward(p, x, cfg)
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, 1)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, 1)
    if cfg.attention == "nsa":
        ck, cv = jax.vmap(lambda k1, v1: compression.compress_kv(p["nsa"], k1, v1, cfg.nsa))(k, v)
        n = min(ck.shape[1], cache["cmp_k"].shape[1])
        cache["cmp_k"] = cache["cmp_k"].at[:, :n].set(ck[:, :n].astype(cache["cmp_k"].dtype))
        cache["cmp_v"] = cache["cmp_v"].at[:, :n].set(cv[:, :n].astype(cache["cmp_v"].dtype))
    return y, cache


def _emit_cmp_token(p, cfg, win_k, win_v):
    """Compress one complete (l,)-token window into a single summary token.

    win_k/win_v: (B, l, h_k, d) -> (ck, cv): (B, h_k, d).
    """
    nsa = cfg.nsa
    one = dataclasses.replace(nsa, cmp_block_size=nsa.cmp_block_size,
                              cmp_stride=nsa.cmp_block_size)
    ck, cv = jax.vmap(lambda k1, v1: compression.compress_kv(p["nsa"], k1, v1, one)
                      )(win_k, win_v)
    return ck[:, 0], cv[:, 0]


def _update_cmp_cache(p, cfg, cache, pos):
    """Emit the newest compression token per slot if its stride boundary was
    crossed.  pos: (B,) absolute positions (per-slot, continuous batching)."""
    nsa = cfg.nsa
    l, st = nsa.cmp_block_size, nsa.cmp_stride
    b = pos.shape[0]
    new_len = pos + 1
    has_new = (new_len >= l) & ((new_len - l) % st == 0)     # (B,)
    j = jnp.maximum((new_len - l) // st, 0)                  # cmp token index
    rows = (j * st)[:, None] + jnp.arange(l)[None, :]        # (B, l)
    b_idx = jnp.arange(b)
    win_k = cache["k"][b_idx[:, None], rows]                 # (B, l, h_k, d)
    win_v = cache["v"][b_idx[:, None], rows]
    ck, cv = _emit_cmp_token(p, cfg, win_k, win_v)

    cache = dict(cache)
    tgt = jnp.where(has_new, jnp.minimum(j, cache["cmp_k"].shape[1] - 1), 0)
    sel = has_new[:, None, None]
    new_ck = jnp.where(sel, ck.astype(cache["cmp_k"].dtype), cache["cmp_k"][b_idx, tgt])
    new_cv = jnp.where(sel, cv.astype(cache["cmp_v"].dtype), cache["cmp_v"][b_idx, tgt])
    cache["cmp_k"] = cache["cmp_k"].at[b_idx, tgt].set(new_ck)
    cache["cmp_v"] = cache["cmp_v"].at[b_idx, tgt].set(new_cv)
    return cache


def attention_decode(p, x_t, cache, pos, cfg):
    """One decode step. x_t: (B,D); pos: scalar or (B,) absolute positions.

    A (B,) vector enables continuous batching: every slot decodes at its own
    depth into the cache (variable-length traffic).  Scalar pos broadcasts.
    """
    b = x_t.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    x1 = x_t[:, None, :]
    q, k, v = _qkv(p, x1, cfg, pos[:, None])             # (B,1,h,dk) ...
    b_idx = jnp.arange(b)
    cache = dict(cache)
    cache["k"] = cache["k"].at[b_idx, pos].set(k[:, 0].astype(cache["k"].dtype))
    cache["v"] = cache["v"].at[b_idx, pos].set(v[:, 0].astype(cache["v"].dtype))

    if cfg.attention == "nsa":
        cache = _update_cmp_cache(p, cfg, cache, pos)
        gates = gating.apply_gates(p["nsa"], x_t)        # (B,h,3)
        fn = lambda q1, kc, vc, ck, cv, g1, p1: uattn.nsa_attention(
            p["nsa"], g1, q1, kc, vc, {"cmp_k": ck, "cmp_v": cv, "pos": p1},
            cfg=cfg.nsa, mode="decode")
        o = jax.vmap(fn)(q[:, 0], cache["k"], cache["v"],
                         cache["cmp_k"], cache["cmp_v"], gates, pos)
    else:
        window = cfg.swa_window if cfg.attention == "swa" else None
        span = cache["k"].shape[1]
        key_pos = jnp.arange(span)
        mask = key_pos[None, :] <= pos[:, None]          # (B, span)
        if window is not None:
            mask &= key_pos[None, :] > (pos[:, None] - window)
        from repro.core.reference import _gqa_out, _gqa_scores, _safe_softmax
        def fn(q1, kc, vc, m1):
            scores = _gqa_scores(q1, kc)
            probs, _ = _safe_softmax(scores, m1[None, None, :])
            return _gqa_out(probs, vc).astype(q1.dtype)
        o = jax.vmap(fn)(q[:, 0:1], cache["k"], cache["v"], mask)
        o = o[:, 0]
    o = o.reshape(b, 1, cfg.n_heads, -1)
    return _out_proj(p, o, cfg)[:, 0], cache


# ------------------------------------------------------------- paged decode
def init_paged_attn_cache(cfg, num_pages: int, num_cmp_pages: int):
    """Per-layer paged KV storage: raw-token pages + compressed-token pages.

    Page size equals ``cfg.nsa.block_size`` so a selected NSA block IS one
    physical page — the selected branch reads exactly the pages the page
    table names.  Page 0 of each pool is a reserved dump page (never
    allocated); idle slots and masked writes land there.
    """
    dtype = jnp.dtype(cfg.dtype)
    pp = cfg.nsa.block_size
    if cfg.mla is not None:
        dk = cfg.mla.kv_lora + cfg.mla.rope_dim
        dv, hk = cfg.mla.kv_lora, 1
    else:
        dk = dv = cfg.hd()
        hk = cfg.n_kv_heads
    cache = {
        "k_pages": jnp.zeros((num_pages, pp, hk, dk), dtype),
        "v_pages": jnp.zeros((num_pages, pp, hk, dv), dtype),
    }
    if cfg.attention == "nsa":
        cache["cmp_k_pages"] = jnp.zeros((num_cmp_pages, pp, hk, dk), dtype)
        cache["cmp_v_pages"] = jnp.zeros((num_cmp_pages, pp, hk, dv), dtype)
    return cache


def _paged_emit_cmp(p, cfg, layer_cache, tables, pos, active=None):
    """Per-slot stride-boundary compressed-token emission on paged storage.

    pos: (B,) position of the token just written; emits cmp token
    ``j = (pos+1-l)/st`` for slots that crossed a boundary, writing it through
    the compressed-page table (dump page 0 otherwise).  ``active`` (B,) bool
    additionally masks slots whose decode row is inert this dispatch (fused
    mixed tick: slots mid-prefill carry REAL page tables, so their ride-along
    emission must be forced onto the dump page).
    """
    nsa = cfg.nsa
    l, st = nsa.cmp_block_size, nsa.cmp_stride
    new_len = pos + 1
    has_new = (new_len >= l) & ((new_len - l) % st == 0)           # (B,)
    if active is not None:
        has_new &= active
    j = jnp.maximum((new_len - l) // st, 0)
    rows = (j * st)[:, None] + jnp.arange(l)[None, :]              # (B, l)
    win_k = jax.vmap(gather_rows, in_axes=(None, 0, 0))(
        layer_cache["k_pages"], tables["page_table"], rows)        # (B,l,hk,dk)
    win_v = jax.vmap(gather_rows, in_axes=(None, 0, 0))(
        layer_cache["v_pages"], tables["page_table"], rows)
    ck, cv = _emit_cmp_token(p, cfg, win_k, win_v)                 # (B,hk,d)

    layer_cache = dict(layer_cache)
    layer_cache["cmp_k_pages"] = scatter_rows(
        layer_cache["cmp_k_pages"], tables["cmp_table"], j[:, None],
        ck[:, None], valid=has_new[:, None],
        min_pos=tables.get("cmp_write_floor"))
    layer_cache["cmp_v_pages"] = scatter_rows(
        layer_cache["cmp_v_pages"], tables["cmp_table"], j[:, None],
        cv[:, None], valid=has_new[:, None],
        min_pos=tables.get("cmp_write_floor"))
    return layer_cache


def paged_attention_decode(p, x_t, layer_cache, tables, pos, cfg, *,
                           active=None):
    """One decode step on paged KV storage (continuous batching).

    x_t: (B, D); pos: (B,) per-slot absolute positions;
    tables: {"page_table": (B, max_pages), "cmp_table": (B, max_cmp_pages)}.
    ``active`` (B,) bool masks rows that must ride along inertly (all writes
    to the dump page) — the fused mixed tick passes the decode-slot mask so
    slots mid-prefill, which carry real page tables, stay untouched.

    The NSA path reads only the pages its branches touch: compressed pages,
    the top-T selected pages (page == NSA block), and the sliding-window
    pages — one batched dispatch through ``repro.attention`` (the Pallas
    paged-decode kernel unless ``cfg.nsa.policy.paged_backend`` says
    otherwise).
    """
    b = x_t.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q, k, v = _qkv(p, x_t[:, None, :], cfg, pos[:, None])
    kv_valid = None if active is None else active[:, None]
    layer_cache = dict(layer_cache)
    layer_cache["k_pages"] = scatter_rows(
        layer_cache["k_pages"], tables["page_table"], pos[:, None], k,
        valid=kv_valid, min_pos=tables.get("write_floor"))
    layer_cache["v_pages"] = scatter_rows(
        layer_cache["v_pages"], tables["page_table"], pos[:, None], v,
        valid=kv_valid, min_pos=tables.get("write_floor"))

    if cfg.attention == "nsa":
        layer_cache = _paged_emit_cmp(p, cfg, layer_cache, tables, pos,
                                      active=active)
        gates = gating.apply_gates(p["nsa"], x_t)                  # (B,h,3)
        n_cmp_max = tables["cmp_table"].shape[1] * cfg.nsa.block_size
        cmp_rows = jnp.arange(n_cmp_max)
        cmp_k = jax.vmap(gather_rows, in_axes=(None, 0, None))(
            layer_cache["cmp_k_pages"], tables["cmp_table"], cmp_rows)
        cmp_v = jax.vmap(gather_rows, in_axes=(None, 0, None))(
            layer_cache["cmp_v_pages"], tables["cmp_table"], cmp_rows)
        # one batched dispatch for the whole slot batch; the registry
        # resolves cfg.nsa.policy.paged_backend ("auto" -> paged_kernel)
        o = uattn.nsa_attention(
            p["nsa"], gates, q[:, 0], layer_cache["k_pages"],
            layer_cache["v_pages"],
            {"page_tables": tables["page_table"], "cmp_k": cmp_k,
             "cmp_v": cmp_v, "pos": pos},
            cfg=cfg.nsa, mode="paged_decode")
    else:
        # full / swa reference: gather the visible span through the page table
        span = tables["page_table"].shape[1] * cfg.nsa.block_size
        if cfg.attention == "swa":
            w = cfg.swa_window
            span = min(span, w)
            rows = pos[:, None] - (span - 1) + jnp.arange(span)[None, :]
        else:
            rows = jnp.broadcast_to(jnp.arange(span)[None, :], (b, span))
        rows_c = jnp.clip(rows, 0, None)
        k_view = jax.vmap(gather_rows, in_axes=(None, 0, 0))(
            layer_cache["k_pages"], tables["page_table"], rows_c)
        v_view = jax.vmap(gather_rows, in_axes=(None, 0, 0))(
            layer_cache["v_pages"], tables["page_table"], rows_c)
        mask = (rows >= 0) & (rows <= pos[:, None])
        if cfg.attention == "swa":
            mask &= rows > (pos[:, None] - cfg.swa_window)
        from repro.core.reference import _gqa_out, _gqa_scores, _safe_softmax
        def fn(q1, kc, vc, m1):
            probs, _ = _safe_softmax(_gqa_scores(q1, kc), m1[None, None, :])
            return _gqa_out(probs, vc).astype(q1.dtype)
        o = jax.vmap(fn)(q[:, 0:1], k_view, v_view, mask)[:, 0]
    o = o.reshape(b, 1, cfg.n_heads, -1)
    return _out_proj(p, o, cfg)[:, 0], layer_cache


def paged_attention_prefill_chunks(p, x_c, layer_cache, tables, t0, length,
                                   cfg):
    """Chunked prefill of a BATCH of slots into paged storage — one dispatch.

    x_c: (B, C, D) per-slot chunks of hidden states at absolute positions
    [t0_b, t0_b + C); tables: {"page_table": (B, max_pages), "cmp_table":
    (B, max_cmp_pages)}; t0/length: (B,) per-slot chunk offset and true
    prompt length.  Slots whose chunk lies entirely beyond their prompt (or
    padding slots with an all-dump-page table) write only to the dump page
    and contribute masked (zero) outputs, so a fixed-shape jit streams any
    mix of prompt lengths.  Attends chunk queries against the whole paged
    prefix (causally masked).
    """
    b, c, _ = x_c.shape
    pos_c = t0[:, None] + jnp.arange(c)                            # (B, C)
    q, k, v = _qkv(p, x_c, cfg, pos_c)                             # (B,C,h,d)…
    layer_cache = dict(layer_cache)
    layer_cache["k_pages"] = scatter_rows(
        layer_cache["k_pages"], tables["page_table"], pos_c, k,
        valid=pos_c < length[:, None], min_pos=tables.get("write_floor"))
    layer_cache["v_pages"] = scatter_rows(
        layer_cache["v_pages"], tables["page_table"], pos_c, v,
        valid=pos_c < length[:, None], min_pos=tables.get("write_floor"))

    s_max = tables["page_table"].shape[1] * cfg.nsa.block_size
    view_rows = jnp.arange(s_max)
    k_view = jax.vmap(gather_rows, in_axes=(None, 0, None))(
        layer_cache["k_pages"], tables["page_table"], view_rows)   # (B,S,hk,d)
    v_view = jax.vmap(gather_rows, in_axes=(None, 0, None))(
        layer_cache["v_pages"], tables["page_table"], view_rows)
    q_mask = pos_c < length[:, None]                               # padding

    if cfg.attention == "nsa":
        nsa = cfg.nsa
        l, st = nsa.cmp_block_size, nsa.cmp_stride
        # emit every cmp token whose window completes inside this chunk:
        # ends e(j) = j*st + l - 1 in [t0, t0+C)  ->  at most C//st + 1 tokens
        max_emit = c // st + 1
        j0 = jnp.maximum(-((l - 1 - t0) // st), 0)     # ceil((t0-l+1)/st)
        js = j0[:, None] + jnp.arange(max_emit)                    # (B, E)
        ends = js * st + l - 1
        ok = ((ends >= t0[:, None]) & (ends < t0[:, None] + c)
              & (ends < length[:, None]))
        wrows = (js * st)[:, :, None] + jnp.arange(l)[None, None, :]  # (B,E,l)
        gather_w = jax.vmap(jax.vmap(gather_rows, in_axes=(None, None, 0)),
                            in_axes=(None, 0, 0))
        win_k = gather_w(layer_cache["k_pages"], tables["page_table"], wrows)
        win_v = gather_w(layer_cache["v_pages"], tables["page_table"], wrows)
        ck, cv = _emit_cmp_token(p, cfg, win_k.reshape((b * max_emit,) + win_k.shape[2:]),
                                 win_v.reshape((b * max_emit,) + win_v.shape[2:]))
        ck = ck.reshape((b, max_emit) + ck.shape[1:])              # (B,E,hk,d)
        cv = cv.reshape((b, max_emit) + cv.shape[1:])
        layer_cache["cmp_k_pages"] = scatter_rows(
            layer_cache["cmp_k_pages"], tables["cmp_table"], js, ck, valid=ok,
            min_pos=tables.get("cmp_write_floor"))
        layer_cache["cmp_v_pages"] = scatter_rows(
            layer_cache["cmp_v_pages"], tables["cmp_table"], js, cv, valid=ok,
            min_pos=tables.get("cmp_write_floor"))

        n_cmp_max = tables["cmp_table"].shape[1] * nsa.block_size
        cmp_rows = jnp.arange(n_cmp_max)
        cmp_k = jax.vmap(gather_rows, in_axes=(None, 0, None))(
            layer_cache["cmp_k_pages"], tables["cmp_table"], cmp_rows)
        cmp_v = jax.vmap(gather_rows, in_axes=(None, 0, None))(
            layer_cache["cmp_v_pages"], tables["cmp_table"], cmp_rows)
        gates = gating.apply_gates(p["nsa"], x_c)                  # (B,C,h,3)
        sel_map = jnp.asarray(compression.cmp_to_sel_map(
            n_cmp_max, nsa.num_kv_blocks(s_max), nsa))
        sel_fn = uattn.sparse_selected_fn(nsa)   # honors policy union/gather
        o, _ = jax.vmap(
            lambda kv1, vv1, ck1, cv1, q1, g1, p1: sparse._nsa_chunk(
                p["nsa"], nsa, kv1, vv1, ck1, cv1, sel_map, (q1, g1, p1),
                selected_fn=sel_fn))(
                    k_view, v_view, cmp_k, cmp_v, q, gates, pos_c)
    else:
        key_pos = jnp.arange(s_max)
        mask = key_pos[None, None, :] <= pos_c[:, :, None]         # (B,C,S)
        if cfg.attention == "swa":
            mask &= key_pos[None, None, :] > (pos_c[:, :, None] - cfg.swa_window)
        from repro.core.reference import _gqa_out, _gqa_scores, _safe_softmax
        def one(q1, kv1, vv1, m1):
            probs, _ = _safe_softmax(_gqa_scores(q1, kv1), m1[:, None, :])
            return _gqa_out(probs, vv1).astype(q1.dtype)
        o = jax.vmap(one)(q, k_view, v_view, mask)
    o = jnp.where(q_mask[:, :, None, None],
                  o.reshape(b, c, cfg.n_heads, -1), 0)
    return _out_proj(p, o, cfg), layer_cache


def paged_attention_prefill_chunk(p, x_c, layer_cache, tables, t0, length, cfg):
    """Single-slot chunked prefill (compat wrapper over the batched path).

    x_c: (C, D); tables: {"page_table": (max_pages,), "cmp_table":
    (max_cmp_pages,)}; t0/length: scalars.
    """
    o, layer_cache = paged_attention_prefill_chunks(
        p, x_c[None], layer_cache,
        {k: v[None] for k, v in tables.items()},
        jnp.asarray(t0)[None], jnp.asarray(length)[None], cfg)
    return o[0], layer_cache
