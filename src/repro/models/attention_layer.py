"""Attention layer: GQA / MLA projections around the NSA-FSA core.

Attention kinds (cfg.attention): "nsa" (paper technique), "full", "swa".

MLA (DeepSeek-V2) is implemented in *absorbed* form: attention runs in the
512-d latent space with a single shared KV head (q/k = latent ⊕ decoupled
RoPE part, v = latent), and the per-head value up-projection W_uv is applied
to the attention output.  This is mathematically identical to materialising
the 16 KV heads (associativity of the matmuls) and lets NSA's compression /
selection / sliding machinery — and the FSA kernels — operate on the latent
cache directly, which is also the correct decode-time layout.  See DESIGN.md
§Arch-applicability.

Decode keeps a raw KV cache plus incrementally-updated NSA compression
caches, so per-token cost stays O(N/stride + T·B_K + W).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import attention as core_attn
from repro.core import compression, gating, sparse
from repro.models.layers import apply_rope, dense_init, rms_norm
from repro.parallel.axes import shard


# ------------------------------------------------------------------ params
def init_attention(key, cfg) -> dict:
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    p = {}
    if cfg.mla is not None:
        m = cfg.mla
        dk_lat = m.kv_lora + m.rope_dim
        p["w_q"] = dense_init(ks[0], (d, h * (m.nope_dim + m.rope_dim)), dtype)
        p["w_dkv"] = dense_init(ks[1], (d, m.kv_lora), dtype)
        p["kv_norm"] = jnp.zeros((m.kv_lora,), dtype)
        p["w_kr"] = dense_init(ks[2], (d, m.rope_dim), dtype)
        # absorbed projections: q->latent (per head), latent->value head
        p["w_uk"] = dense_init(ks[3], (h, m.nope_dim, m.kv_lora), dtype)
        p["w_uv"] = dense_init(ks[4], (h, m.kv_lora, hd), dtype)
        p["w_o"] = dense_init(ks[5], (h * hd, d), dtype)
        attn_dk, attn_dv, attn_hk = dk_lat, m.kv_lora, 1
    else:
        p["w_q"] = dense_init(ks[0], (d, h * hd), dtype)
        p["w_k"] = dense_init(ks[1], (d, hk * hd), dtype)
        p["w_v"] = dense_init(ks[2], (d, hk * hd), dtype)
        p["w_o"] = dense_init(ks[3], (h * hd, d), dtype)
        if cfg.use_qkv_bias:
            p["b_q"] = jnp.zeros((h * hd,), dtype)
            p["b_k"] = jnp.zeros((hk * hd,), dtype)
            p["b_v"] = jnp.zeros((hk * hd,), dtype)
        attn_dk, attn_dv, attn_hk = hd, hd, hk
    if cfg.attention == "nsa":
        p["nsa"] = {
            **compression.init_compression_params(ks[6], cfg.nsa, attn_dk,
                                                  attn_dv, dtype),
            **gating.init_gate_params(ks[7], d, h, dtype),
        }
    del attn_hk
    return p


# -------------------------------------------------------------- projections
def _qkv(p, x, cfg, pos):
    """x: (B,S,D) -> q (B,S,h,dk), k (B,S,h_k,dk), v (B,S,h_k,dv)."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd()
    if cfg.mla is not None:
        m = cfg.mla
        c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)   # (B,S,L)
        k_rope = apply_rope(
            (x @ p["w_kr"])[:, :, None, :], pos, cfg.rope_theta)      # (B,S,1,r)
        q = (x @ p["w_q"]).reshape(b, s, h, m.nope_dim + m.rope_dim)
        q_nope, q_rope = q[..., :m.nope_dim], q[..., m.nope_dim:]
        q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
        q_lat = jnp.einsum("bshn,hnl->bshl", q_nope, p["w_uk"])       # absorbed
        q_full = jnp.concatenate([q_lat, q_rope], axis=-1)            # (B,S,h,L+r)
        k_full = jnp.concatenate([c_kv[:, :, None, :], k_rope], axis=-1)
        return q_full, k_full, c_kv[:, :, None, :]
    hk = cfg.n_kv_heads
    q = x @ p["w_q"] + (p.get("b_q", 0))
    k = x @ p["w_k"] + (p.get("b_k", 0))
    v = x @ p["w_v"] + (p.get("b_v", 0))
    q = apply_rope(q.reshape(b, s, h, hd), pos, cfg.rope_theta)
    k = apply_rope(k.reshape(b, s, hk, hd), pos, cfg.rope_theta)
    return q, k, v.reshape(b, s, hk, hd)


def _out_proj(p, o, cfg):
    """o: (B,S,h,dv_attn) -> (B,S,D)."""
    b, s = o.shape[:2]
    if cfg.mla is not None:
        o = jnp.einsum("bshl,hld->bshd", o, p["w_uv"])
    return o.reshape(b, s, -1) @ p["w_o"]


# ------------------------------------------------------------ full-sequence
def attention_forward(p, x, cfg, *, causal: bool = True):
    """Training / prefill attention over a full sequence. x: (B,S,D)."""
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _qkv(p, x, cfg, pos)
    q = shard(q, "batch", "seq", "heads")
    k = shard(k, "batch", "seq", "kv_heads")
    v = shard(v, "batch", "seq", "kv_heads")

    if cfg.attention == "nsa" and causal:
        gates = gating.apply_gates(p["nsa"], x)
        fn = lambda q1, k1, v1, g1: core_attn.nsa_attention(
            p["nsa"], g1, q1, k1, v1, cfg.nsa, impl=cfg.attn_impl,
            q_chunk=cfg.q_chunk)
        o = jax.vmap(fn)(q, k, v, gates)
    elif cfg.attention == "swa" and causal:
        from repro.kernels import ref as kref
        fn = lambda q1, k1, v1: kref.flash_ref_chunked(
            q1, k1, v1, causal=True, window=cfg.swa_window, q_chunk=cfg.q_chunk)
        o = jax.vmap(fn)(q, k, v)
    else:
        from repro.kernels import ref as kref
        fn = lambda q1, k1, v1: kref.flash_ref_chunked(
            q1, k1, v1, causal=causal, q_chunk=cfg.q_chunk)
        o = jax.vmap(fn)(q, k, v)
    o = shard(o, "batch", "seq", "heads")
    return _out_proj(p, o, cfg)


def cross_attention_forward(p, x, kv_x, cfg):
    """Encoder-decoder cross attention (full, non-causal). kv_x: (B,Senc,D)."""
    b, s, _ = x.shape
    pos = jnp.zeros((b, s), jnp.int32)      # no rope on cross attention
    h, hd, hk = cfg.n_heads, cfg.hd(), cfg.n_kv_heads
    q = (x @ p["w_q"]).reshape(b, s, h, hd)
    k = (kv_x @ p["w_k"]).reshape(b, kv_x.shape[1], hk, hd)
    v = (kv_x @ p["w_v"]).reshape(b, kv_x.shape[1], hk, hd)
    from repro.kernels import ref as kref
    o = jax.vmap(lambda a, b_, c: kref.flash_ref_chunked(a, b_, c, causal=False,
                                                         q_chunk=cfg.q_chunk))(q, k, v)
    return o.reshape(b, s, -1) @ p["w_o"]


# ------------------------------------------------------------------ decode
def init_attn_cache(cfg, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    if cfg.mla is not None:
        dk = cfg.mla.kv_lora + cfg.mla.rope_dim
        dv, hk = cfg.mla.kv_lora, 1
    else:
        dk = dv = cfg.hd()
        hk = cfg.n_kv_heads
    cache = {
        "k": jnp.zeros((batch, max_len, hk, dk), dtype),
        "v": jnp.zeros((batch, max_len, hk, dv), dtype),
    }
    if cfg.attention == "nsa":
        n_cmp = cfg.nsa.num_cmp_blocks(max_len)
        cache["cmp_k"] = jnp.zeros((batch, n_cmp, hk, dk), dtype)
        cache["cmp_v"] = jnp.zeros((batch, n_cmp, hk, dv), dtype)
    return cache


def attention_prefill(p, x, cfg, cache):
    """Run full-seq attention and populate the decode cache. x: (B,S,D)."""
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    _, k, v = _qkv(p, x, cfg, pos)
    y = attention_forward(p, x, cfg)
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, 1)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, 1)
    if cfg.attention == "nsa":
        ck, cv = jax.vmap(lambda k1, v1: compression.compress_kv(p["nsa"], k1, v1, cfg.nsa))(k, v)
        n = min(ck.shape[1], cache["cmp_k"].shape[1])
        cache["cmp_k"] = cache["cmp_k"].at[:, :n].set(ck[:, :n].astype(cache["cmp_k"].dtype))
        cache["cmp_v"] = cache["cmp_v"].at[:, :n].set(cv[:, :n].astype(cache["cmp_v"].dtype))
    return y, cache


def _update_cmp_cache(p, cfg, cache, pos):
    """Emit the newest compression token if a stride boundary was crossed."""
    nsa = cfg.nsa
    l, st = nsa.cmp_block_size, nsa.cmp_stride
    new_len = pos + 1
    has_new = (new_len >= l) & ((new_len - l) % st == 0)
    j = jnp.maximum((new_len - l) // st, 0)              # cmp token index
    start = j * st

    def emit(cache):
        win_k = jax.lax.dynamic_slice_in_dim(cache["k"], start, l, axis=1)
        win_v = jax.lax.dynamic_slice_in_dim(cache["v"], start, l, axis=1)
        ck, cv = jax.vmap(lambda k1, v1: compression.compress_kv(p["nsa"], k1, v1,
                    dataclasses.replace(nsa, cmp_block_size=l, cmp_stride=l)))(win_k, win_v)
        cache = dict(cache)
        cache["cmp_k"] = jax.lax.dynamic_update_slice(
            cache["cmp_k"], ck.astype(cache["cmp_k"].dtype), (0, j, 0, 0))
        cache["cmp_v"] = jax.lax.dynamic_update_slice(
            cache["cmp_v"], cv.astype(cache["cmp_v"].dtype), (0, j, 0, 0))
        return cache

    return jax.lax.cond(has_new, emit, lambda c: dict(c), cache)


def attention_decode(p, x_t, cache, pos, cfg):
    """One decode step. x_t: (B,D); pos: scalar absolute position."""
    b = x_t.shape[0]
    x1 = x_t[:, None, :]
    pos_b = jnp.broadcast_to(pos, (b, 1))
    q, k, v = _qkv(p, x1, cfg, pos_b)                    # (B,1,h,dk) ...
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))

    if cfg.attention == "nsa":
        cache = _update_cmp_cache(p, cfg, cache, pos)
        gates = gating.apply_gates(p["nsa"], x_t)        # (B,h,3)
        fn = lambda q1, kc, vc, ck, cv, g1: sparse.nsa_decode_step(
            p["nsa"], g1, q1, kc, vc, ck, cv, pos, cfg.nsa)
        o = jax.vmap(fn)(q[:, 0], cache["k"], cache["v"],
                         cache["cmp_k"], cache["cmp_v"], gates)
    else:
        window = cfg.swa_window if cfg.attention == "swa" else None
        span = cache["k"].shape[1]
        key_pos = jnp.arange(span)
        mask = key_pos <= pos
        if window is not None:
            mask &= key_pos > pos - window
        from repro.core.reference import _gqa_out, _gqa_scores, _safe_softmax
        def fn(q1, kc, vc):
            scores = _gqa_scores(q1, kc)
            probs, _ = _safe_softmax(scores, mask[None, None, :])
            return _gqa_out(probs, vc).astype(q1.dtype)
        o = jax.vmap(fn)(q[:, 0:1], cache["k"], cache["v"])
        o = o[:, 0]
    o = o.reshape(b, 1, cfg.n_heads, -1)
    return _out_proj(p, o, cfg)[:, 0], cache
