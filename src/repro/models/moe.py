"""Mixture-of-Experts layer: top-k token-choice routing with capacity factor.

Dispatch uses scatter-add into an (E, cap, D) expert buffer and combine uses
gathers — O(E·cap·D) memory, no (tokens × E × cap) one-hot tensors, so it
scales to production shapes.  Experts are sharded over the "expert" logical
axis (expert parallelism); the scatter/gather across the expert axis lowers
to the MoE all-to-all under pjit.  Shared experts (DeepSeek style) run
densely for every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.parallel.axes import shard


def init_moe(key, cfg) -> dict:
    m = cfg.moe
    d, f = cfg.d_model, m.d_expert
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.num_experts), dtype, scale=0.02),
        "w_gate": dense_init(ks[1], (m.num_experts, d, f), dtype),
        "w_in": dense_init(ks[2], (m.num_experts, d, f), dtype),
        "w_out": dense_init(ks[3], (m.num_experts, f, d), dtype),
    }
    if m.num_shared:
        p["shared"] = {
            "w_gate": dense_init(ks[4], (d, f * m.num_shared), dtype),
            "w_in": dense_init(jax.random.fold_in(ks[4], 1), (d, f * m.num_shared), dtype),
            "w_out": dense_init(jax.random.fold_in(ks[4], 2), (f * m.num_shared, d), dtype),
        }
    return p


def apply_moe(p, x, cfg):
    """x: (B,S,D) -> ((B,S,D), aux load-balancing loss)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt @ p["router"]).astype(jnp.float32)            # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)               # (t, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    cap = int(max(1, m.capacity_factor * t * m.top_k / m.num_experts))
    # slot of each assignment inside its expert's capacity buffer
    flat_e = top_e.reshape(-1)                                 # (t·k,) row-major:
    eo = jax.nn.one_hot(flat_e, m.num_experts, dtype=jnp.int32)
    pos_flat = ((jnp.cumsum(eo, axis=0) - eo) * eo).sum(-1)    # (t·k,)
    pos = pos_flat.reshape(t, m.top_k)
    keep = pos < cap

    xe = jnp.zeros((m.num_experts, cap, d), xt.dtype)
    for kk in range(m.top_k):                                  # unrolled, k ≤ 8
        contrib = jnp.where(keep[:, kk, None], xt, 0)
        xe = xe.at[top_e[:, kk], jnp.minimum(pos[:, kk], cap - 1)].add(contrib)
    xe = shard(xe, "expert", "cap", "embed")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["w_in"])
    h = shard(h, "expert", "cap", "mlp_unsharded")
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"])             # (E,cap,D)
    ye = shard(ye, "expert", "cap", "embed")

    y = jnp.zeros_like(xt)
    for kk in range(m.top_k):
        gath = ye[top_e[:, kk], jnp.minimum(pos[:, kk], cap - 1)]
        w = (top_p[:, kk] * keep[:, kk]).astype(xt.dtype)
        y = y + gath * w[:, None]

    if m.num_shared:
        sp = p["shared"]
        hs = jax.nn.silu(xt @ sp["w_gate"]) * (xt @ sp["w_in"])
        y = y + hs @ sp["w_out"]

    # Switch-style load-balancing aux loss
    frac_tokens = jax.nn.one_hot(top_e[:, 0], m.num_experts).mean(0)
    frac_probs = probs.mean(0)
    aux = m.num_experts * jnp.sum(frac_tokens * frac_probs)
    return y.reshape(b, s, d).astype(x.dtype), aux
