"""Decoder-only transformer stack: dense, MoE, SSM and hybrid families.

Layers are scanned (stacked params, ``lax.scan``) with rematerialization so
the compiled HLO stays small and activation memory is one layer deep.  The
zamba2-style hybrid scans *groups* of (period × mamba + shared-attention)
blocks, reusing one set of shared-attention weights across groups.

The LM loss is computed in sequence chunks so the (B, S, vocab) logits tensor
is never materialised (vocab is TP-sharded).

All attention math below the layer stack dispatches through the
``repro.attention`` backend registry (``attention_layer`` passes
``cfg.attn_impl`` / ``cfg.nsa.policy`` through); this module never names an
implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention_layer as attn
from repro.models import mamba2, moe
from repro.models.layers import (apply_mlp, cross_entropy, dense_init,
                                 init_mlp, rms_norm, softcap)
from repro.parallel.axes import shard

AUX_LOSS_WEIGHT = 0.01


# ------------------------------------------------------------------- blocks
def init_block(key, cfg):
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn.init_attention(ks[0], cfg),
    }
    if cfg.moe is not None:
        p["moe"] = moe.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    return p


def apply_block(p, x, cfg):
    """x: (B,S,D) -> (x', aux_loss)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    h = attn.attention_forward(p["attn"], h, cfg)
    x = x + h
    x = shard(x, "batch", "seq_sp", "embed")
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        h, aux = moe.apply_moe(p["moe"], h, cfg)
    else:
        h, aux = apply_mlp(p["mlp"], h, cfg.mlp), 0.0
    x = x + h
    return shard(x, "batch", "seq_sp", "embed"), aux


def init_mamba_block(key, cfg):
    return {
        "ln": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
        "mixer": mamba2.init_mamba(key, cfg),
    }


def apply_mamba_block(p, x, cfg):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    h, _ = mamba2.mamba_forward(p["mixer"], h, cfg)
    return shard(x + h, "batch", "seq_sp", "embed")


# ------------------------------------------------------------------- stacks
def _stack_init(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_lm(key, cfg):
    ks = jax.random.split(key, 5)
    dtype = jnp.dtype(cfg.dtype)
    p = {
        "embed": dense_init(ks[0], (cfg.padded_vocab(), cfg.d_model), dtype, scale=0.02),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.padded_vocab()), dtype)
    if cfg.family == "hybrid":
        period = cfg.shared_attn_period
        n_groups, rem = divmod(cfg.n_layers, period)
        p["groups"] = _stack_init(
            lambda k: _stack_init(lambda k2: init_mamba_block(k2, cfg), k, period),
            ks[2], n_groups)
        if rem:
            p["tail"] = _stack_init(lambda k: init_mamba_block(k, cfg), ks[3], rem)
        p["shared_attn"] = init_block(ks[4], cfg)
    elif cfg.family == "ssm":
        p["layers"] = _stack_init(lambda k: init_mamba_block(k, cfg), ks[2],
                                  cfg.n_layers)
    else:
        p["layers"] = _stack_init(lambda k: init_block(k, cfg), ks[2],
                                  cfg.n_layers)
    if cfg.family == "vlm":
        p["img_proj"] = dense_init(ks[3], (cfg.d_model, cfg.d_model), dtype)
    return p


def _scan_blocks(p_stack, x, body):
    def step(carry, p_layer):
        x, aux = carry
        x, a = body(p_layer, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(step, (x, 0.0), p_stack)
    return x, aux


def backbone(params, x, cfg):
    """Hidden-states backbone over embedded inputs x: (B,S,D)."""
    if cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_body(p_group, x):
            body = jax.checkpoint(lambda p, h: (apply_mamba_block(p, h, cfg), 0.0)) \
                if cfg.remat else (lambda p, h: (apply_mamba_block(p, h, cfg), 0.0))
            x, _ = _scan_blocks(p_group, x, body)
            x, _ = apply_block(shared, x, cfg)
            return x, 0.0

        gb = jax.checkpoint(group_body) if cfg.remat else group_body
        x, aux = _scan_blocks(params["groups"], x, gb)
        if "tail" in params:
            body = lambda p, h: (apply_mamba_block(p, h, cfg), 0.0)
            x, _ = _scan_blocks(params["tail"], x,
                                jax.checkpoint(body) if cfg.remat else body)
        return x, aux
    if cfg.family == "ssm":
        body = lambda p, h: (apply_mamba_block(p, h, cfg), 0.0)
    else:
        body = lambda p, h: apply_block(p, h, cfg)
    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        return _scan_blocks(params["layers"], x, body)
    x_, aux = x, 0.0
    for i in range(cfg.n_layers):
        p_i = jax.tree.map(lambda a: a[i], params["layers"])
        x_, a = body(p_i, x_)
        aux += a
    return x_, aux


def embed_tokens(params, tokens, cfg):
    x = params["embed"][tokens]                 # gather (B,S,D)
    return shard(x, "batch", "seq_sp", "embed")


def _head(params, x, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ w
    logits = shard(logits, "batch", "seq", "vocab")
    logits = softcap(logits, cfg.logit_softcap)
    if cfg.padded_vocab() != cfg.vocab:   # mask vocab-padding classes
        logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab,
                           logits, -1e30)
    return logits


def lm_loss(params, batch, cfg, *, loss_chunk: int = 1024):
    """batch: {"tokens": (B,S) int32, "labels": (B,S) int32 (-100 masked)}.

    Vision batches add "img_embeds": (B, n_img, D) — prepended as a prefix.
    """
    tokens, labels = batch["tokens"], batch["labels"]
    x = embed_tokens(params, tokens, cfg)
    n_img = 0
    if cfg.family == "vlm" and "img_embeds" in batch:
        img = batch["img_embeds"].astype(x.dtype) @ params["img_proj"]
        x = jnp.concatenate([img, x], axis=1)
        n_img = img.shape[1]
    x, aux = backbone(params, x, cfg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if n_img:
        x = x[:, n_img:]

    b, s, d = x.shape
    c = min(loss_chunk, s)
    pad = (c - s % c) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)

    def chunk_loss(args):
        xc, lc = args
        logits = _head(params, xc, cfg)
        loss_sum, cnt = cross_entropy(logits, lc)
        return loss_sum * cnt, cnt

    xs = (x.reshape(b, -1, c, d).transpose(1, 0, 2, 3),
          labels.reshape(b, -1, c).transpose(1, 0, 2))
    sums, cnts = jax.lax.map(chunk_loss, xs)
    loss = sums.sum() / jnp.maximum(cnts.sum(), 1)
    return loss + AUX_LOSS_WEIGHT * aux, {"ce": loss, "aux": aux,
                                          "tokens": cnts.sum()}


def lm_logits(params, batch, cfg):
    """Full-sequence logits (prefill path / small-scale eval)."""
    x = embed_tokens(params, batch["tokens"], cfg)
    if cfg.family == "vlm" and "img_embeds" in batch:
        img = batch["img_embeds"].astype(x.dtype) @ params["img_proj"]
        x = jnp.concatenate([img, x], axis=1)
    x, _ = backbone(params, x, cfg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _head(params, x, cfg)


# ------------------------------------------------------------------- prefill
def _prefill_attn_block(p, x, cache, cfg):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    h, cache = attn.attention_prefill(p["attn"], h, cfg, cache)
    x = x + h
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        h, _ = moe.apply_moe(p["moe"], h, cfg)
    else:
        h = apply_mlp(p["mlp"], h, cfg.mlp)
    return x + h, cache


def _prefill_mamba_block(p, x, cache, cfg):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    h, (conv, ssm) = mamba2.mamba_forward(p["mixer"], h, cfg)
    return x + h, {"conv": conv.astype(cache["conv"].dtype), "ssm": ssm}


def lm_prefill(params, cache, batch, cfg):
    """Populate decode caches from a prompt. Returns (last-position logits, cache)."""
    x = embed_tokens(params, batch["tokens"], cfg)
    if cfg.family == "vlm" and "img_embeds" in batch:
        img = batch["img_embeds"].astype(x.dtype) @ params["img_proj"]
        x = jnp.concatenate([img, x], axis=1)

    if cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group(x, args):
            p_g, c_g, c_attn = args

            def inner(x, a2):
                p_l, c_l = a2
                return _prefill_mamba_block(p_l, x, c_l, cfg)

            x, c_g = jax.lax.scan(inner, x, (p_g, c_g))
            x, c_attn = _prefill_attn_block(shared, x, c_attn, cfg)
            return x, (c_g, c_attn)

        x, (cg, ca) = jax.lax.scan(group, x, (params["groups"], cache["groups"],
                                              cache["shared_attn"]))
        cache = dict(cache, groups=cg, shared_attn=ca)
        if "tail" in params:
            x, ct = jax.lax.scan(
                lambda x, a2: _prefill_mamba_block(a2[0], x, a2[1], cfg),
                x, (params["tail"], cache["tail"]))
            cache["tail"] = ct
    elif cfg.family == "ssm":
        x, cl = jax.lax.scan(
            lambda x, a2: _prefill_mamba_block(a2[0], x, a2[1], cfg),
            x, (params["layers"], cache["layers"]))
        cache = dict(cache, layers=cl)
    else:
        x, cl = jax.lax.scan(
            lambda x, a2: _prefill_attn_block(a2[0], x, a2[1], cfg),
            x, (params["layers"], cache["layers"]))
        cache = dict(cache, layers=cl)

    x = rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
    return _head(params, x[:, None], cfg)[:, 0], cache


# -------------------------------------------------------------------- decode
def _stack_cache(cache, *ns):
    """Prepend stacking dims (caches are zero-initialised, so just re-zero)."""
    return jax.tree.map(lambda a: jnp.zeros(tuple(ns) + a.shape, a.dtype), cache)


def init_lm_cache(cfg, batch: int, max_len: int):
    if cfg.family == "hybrid":
        period = cfg.shared_attn_period
        n_groups, rem = divmod(cfg.n_layers, period)
        cache = {
            "groups": _stack_cache(mamba2.init_mamba_cache(cfg, batch),
                                   n_groups, period),
            "shared_attn": _stack_cache(attn.init_attn_cache(cfg, batch, max_len),
                                        n_groups),
        }
        if rem:
            cache["tail"] = _stack_cache(mamba2.init_mamba_cache(cfg, batch), rem)
        return cache
    if cfg.family == "ssm":
        return {"layers": _stack_cache(mamba2.init_mamba_cache(cfg, batch),
                                       cfg.n_layers)}
    return {"layers": _stack_cache(attn.init_attn_cache(cfg, batch, max_len),
                                   cfg.n_layers)}


def init_lm_paged_cache(cfg, num_pages: int, num_cmp_pages: int):
    """Paged decode cache (attention families only — ssm/hybrid/encdec carry
    recurrent or cross-attention state that is not paged KV)."""
    if cfg.family in ("ssm", "hybrid", "encdec"):
        raise NotImplementedError(f"no paged cache for family '{cfg.family}'")
    return {"layers": _stack_cache(
        attn.init_paged_attn_cache(cfg, num_pages, num_cmp_pages),
        cfg.n_layers)}


def lm_paged_decode_step(params, cache, tokens, pos, tables, cfg, *,
                         reduce_fn=None):
    """Batched decode on paged storage.

    tokens: (B,) int32; pos: (B,) per-slot absolute positions; tables: the
    shared {"page_table", "cmp_table"} arrays.  Returns (logits (B,V), cache).
    The paged-decode backend (Pallas kernel vs gather reference) is resolved
    per ``cfg.nsa.policy.paged_backend`` inside ``repro.attention``.

    ``reduce_fn`` (tensor-parallel serving): applied to each attention
    output before the residual add.  Under ``shard_map`` with the heads
    split over a mesh axis, the out-projection produces a partial sum —
    pass ``lambda t: jax.lax.psum(t, "model")`` to complete it.
    """
    rf = reduce_fn if reduce_fn is not None else (lambda t: t)
    x = params["embed"][tokens]

    def body(x, args):
        p_l, c_l = args
        h = rms_norm(x, p_l["ln1"], cfg.norm_eps)
        h, c_l = attn.paged_attention_decode(p_l["attn"], h, c_l, tables, pos, cfg)
        x = x + rf(h)
        h = rms_norm(x, p_l["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            h2, _ = moe.apply_moe(p_l["moe"], h[:, None, :], cfg)
            h = h2[:, 0]
        else:
            h = apply_mlp(p_l["mlp"], h, cfg.mlp)
        return x + h, c_l

    x, cl = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    cache = dict(cache, layers=cl)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _head(params, x[:, None], cfg)[:, 0], cache


def lm_paged_prefill_chunks(params, cache, tokens_c, t0, length, tables, cfg,
                            *, reduce_fn=None):
    """Prefill one chunk for a BATCH of slots into paged storage.

    tokens_c: (B, C) int32, slot b's tokens at absolute positions
    [t0_b, t0_b + C) (tail beyond ``length_b`` is padding); t0/length: (B,);
    tables: {"page_table": (B, max_pages), "cmp_table": (B, max_cmp_pages)}.
    Returns (logits (B, C, V), cache) — the engine reads each slot's logit
    at its prompt's last position from the chunk that covers it.  Padding
    slots (length 0, all-dump-page tables) are inert.

    ``reduce_fn``: see ``lm_paged_decode_step`` (tensor-parallel psum over
    the partial attention out-projection).
    """
    rf = reduce_fn if reduce_fn is not None else (lambda t: t)
    x = params["embed"][tokens_c]                          # (B, C, D)

    def body(x, args):
        p_l, c_l = args
        h = rms_norm(x, p_l["ln1"], cfg.norm_eps)
        h, c_l = attn.paged_attention_prefill_chunks(
            p_l["attn"], h, c_l, tables, t0, length, cfg)
        x = x + rf(h)
        h = rms_norm(x, p_l["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            h, _ = moe.apply_moe(p_l["moe"], h, cfg)
        else:
            h = apply_mlp(p_l["mlp"], h, cfg.mlp)
        return x + h, c_l

    x, cl = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    cache = dict(cache, layers=cl)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _head(params, x, cfg), cache


def lm_paged_mixed_step(params, cache, pf_tokens, pf_t0, pf_len,
                        dec_tokens, dec_pos, dec_active, tables, cfg, *,
                        reduce_fn=None):
    """ONE fused dispatch per engine tick: a bounded prefill chunk for
    admitting slots AND one decode token for active slots (vLLM-style
    continuous batching — decode never stalls behind a long co-admitted
    prompt's chunk loop).

    pf_tokens: (B, C) int32 chunk rows at absolute positions
    [pf_t0_b, pf_t0_b + C); pf_len: (B,) true prompt lengths (``pf_len == 0``
    rows are fully inert — slots not prefilling this tick).
    dec_tokens/dec_pos: (B,) decode operands; dec_active: (B,) bool — rows
    with ``False`` (slots mid-prefill or free) ride along with all writes
    routed to the dump page.  A slot is never both (disjoint masks), so the
    two sub-steps share ``tables`` and the per-layer page pools safely.

    ``reduce_fn``: see ``lm_paged_decode_step`` (tensor-parallel psum over
    the partial attention out-projections of BOTH sub-steps).

    Returns (pf_logits (B, C, V), dec_logits (B, V), cache).
    """
    rf = reduce_fn if reduce_fn is not None else (lambda t: t)
    x_pf = params["embed"][pf_tokens]                       # (B, C, D)
    x_dec = params["embed"][dec_tokens]                     # (B, D)

    def body(carry, args):
        x_pf, x_dec = carry
        p_l, c_l = args
        # prefill sub-step (chunk rows; inert where pf_len == 0)
        h = rms_norm(x_pf, p_l["ln1"], cfg.norm_eps)
        h, c_l = attn.paged_attention_prefill_chunks(
            p_l["attn"], h, c_l, tables, pf_t0, pf_len, cfg)
        x_pf = x_pf + rf(h)
        h = rms_norm(x_pf, p_l["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            h, _ = moe.apply_moe(p_l["moe"], h, cfg)
        else:
            h = apply_mlp(p_l["mlp"], h, cfg.mlp)
        x_pf = x_pf + h
        # decode sub-step (one token per active slot)
        h = rms_norm(x_dec, p_l["ln1"], cfg.norm_eps)
        h, c_l = attn.paged_attention_decode(p_l["attn"], h, c_l, tables,
                                             dec_pos, cfg, active=dec_active)
        x_dec = x_dec + rf(h)
        h = rms_norm(x_dec, p_l["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            h2, _ = moe.apply_moe(p_l["moe"], h[:, None, :], cfg)
            h = h2[:, 0]
        else:
            h = apply_mlp(p_l["mlp"], h, cfg.mlp)
        x_dec = x_dec + h
        return (x_pf, x_dec), c_l

    (x_pf, x_dec), cl = jax.lax.scan(body, (x_pf, x_dec),
                                     (params["layers"], cache["layers"]))
    cache = dict(cache, layers=cl)
    x_pf = rms_norm(x_pf, params["final_norm"], cfg.norm_eps)
    x_dec = rms_norm(x_dec, params["final_norm"], cfg.norm_eps)
    return (_head(params, x_pf, cfg),
            _head(params, x_dec[:, None], cfg)[:, 0], cache)


def lm_paged_prefill_chunk(params, cache, tokens_c, t0, length, tables, cfg):
    """Single-slot chunked prefill (compat wrapper over the batched path).

    tokens_c: (C,) int32; t0/length: scalars; tables: this slot's
    {"page_table", "cmp_table"} rows.  Returns (logits (C, V), cache).
    """
    logits, cache = lm_paged_prefill_chunks(
        params, cache, tokens_c[None], jnp.asarray(t0)[None],
        jnp.asarray(length)[None],
        {k: v[None] for k, v in tables.items()}, cfg)
    return logits[0], cache


def _decode_attn_block(p, x_t, cache, pos, cfg):
    h = rms_norm(x_t, p["ln1"], cfg.norm_eps)
    h, cache = attn.attention_decode(p["attn"], h, cache, pos, cfg)
    x_t = x_t + h
    h = rms_norm(x_t, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        h2, _ = moe.apply_moe(p["moe"], h[:, None, :], cfg)
        h = h2[:, 0]
    else:
        h = apply_mlp(p["mlp"], h, cfg.mlp)
    return x_t + h, cache


def _decode_mamba_block(p, x_t, cache, cfg):
    h = rms_norm(x_t, p["ln"], cfg.norm_eps)
    h, conv, ssm = mamba2.mamba_decode_step(p["mixer"], h, cache["conv"],
                                            cache["ssm"], cfg)
    return x_t + h, {"conv": conv, "ssm": ssm}


def lm_decode_step(params, cache, tokens, pos, cfg):
    """tokens: (B,) int32; pos: scalar or (B,) per-slot absolute positions
    (continuous batching decodes every slot at its own depth).
    Returns (logits (B,V), cache)."""
    x = params["embed"][tokens]

    if cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_step(x, args):
            p_g, c_g, c_attn = args

            def inner(x, args2):
                p_l, c_l = args2
                x, c_new = _decode_mamba_block(p_l, x, c_l, cfg)
                return x, c_new

            x, c_g = jax.lax.scan(inner, x, (p_g, c_g))
            x, c_attn = _decode_attn_block(shared, x, c_attn, pos, cfg)
            return x, (c_g, c_attn)

        def outer(x, args):
            x, cs = group_step(x, args)
            return x, cs

        x, (cg, ca) = jax.lax.scan(outer, x,
                                   (params["groups"], cache["groups"],
                                    cache["shared_attn"]))
        cache = dict(cache, groups=cg, shared_attn=ca)
        if "tail" in params:
            def inner(x, args2):
                p_l, c_l = args2
                x, c_new = _decode_mamba_block(p_l, x, c_l, cfg)
                return x, c_new
            x, ct = jax.lax.scan(inner, x, (params["tail"], cache["tail"]))
            cache["tail"] = ct
    elif cfg.family == "ssm":
        def body(x, args):
            p_l, c_l = args
            x, c_new = _decode_mamba_block(p_l, x, c_l, cfg)
            return x, c_new
        x, cl = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        cache = dict(cache, layers=cl)
    else:
        def body(x, args):
            p_l, c_l = args
            x, c_new = _decode_attn_block(p_l, x, c_l, pos, cfg)
            return x, c_new
        x, cl = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        cache = dict(cache, layers=cl)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _head(params, x[:, None], cfg)[:, 0], cache
