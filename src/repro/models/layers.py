"""Shared neural-net building blocks (functional, param-dict based)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) > 1 else 1
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * s).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = ((x32 - mu) ** 2).mean(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, pos, theta: float = 10000.0):
    """x: (..., S, h, d) rotary embedding at positions pos (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))            # (d/2,)
    angles = pos[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- MLPs
def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_out": dense_init(k2, (d_ff, d_model), dtype)}
    if kind == "swiglu":
        p["w_in"] = dense_init(k1, (d_model, d_ff), dtype)
        p["w_gate"] = dense_init(k3, (d_model, d_ff), dtype)
    else:  # relu2 | gelu
        p["w_in"] = dense_init(k1, (d_model, d_ff), dtype)
    return p


def apply_mlp(p, x, kind: str):
    from repro.parallel.axes import shard

    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w_in"]))
    elif kind == "gelu":
        h = jax.nn.gelu(x @ p["w_in"])
    else:
        raise ValueError(kind)
    if h.ndim == 3:
        h = shard(h, "batch", "seq", "mlp")
    else:  # decode step: (B, ff)
        h = shard(h, "batch", "mlp")
    return h @ p["w_out"]


def softcap(logits, cap: float):
    if cap and cap > 0:
        return jnp.tanh(logits / cap) * cap
    return logits


def cross_entropy(logits, labels, ignore_id: int = -100):
    """Mean token-level cross entropy with label masking. logits (..., V)."""
    mask = labels != ignore_id
    labels_safe = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    loss = -jnp.where(mask, ll, 0.0).sum() / jnp.maximum(mask.sum(), 1)
    return loss, mask.sum()
