"""Pallas TPU flash attention (full-attention baseline + sliding-window branch).

Layouts (GQA folded per KV head):
  q: (h_K, Nq·g, d)  rows are token-major, group-head-minor
  k/v: (h_K, Nk, d)
  out: (h_K, Nq·g, d)

Grid: (h_K, num_q_blocks, num_kv_blocks) — kv innermost (sequential,
"arbitrary"); online-softmax state lives in VMEM scratch across kv steps.
Causal/window-violating kv blocks are skipped with ``pl.when`` and their HBM
fetch elided by clamping the kv index map to the last useful block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, g, block_q, block_k, seq_q, seq_k, causal, window):
    iq, ik = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)
    rows = q_ref.shape[1]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # does this kv block intersect the allowed band for this q block?
    q_lo = iq * block_q
    q_hi = q_lo + block_q - 1          # token positions (pre-group-fold)
    k_lo = ik * block_k
    k_hi = k_lo + block_k - 1
    live = k_lo < seq_k
    if causal:
        live &= k_lo <= q_hi + (seq_k - seq_q)
    if window is not None:
        live &= k_hi >= q_lo + (seq_k - seq_q) - (window - 1)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        tok = q_lo + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 0) // g
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 1)
        mask = kpos < seq_k
        if causal:
            mask &= tok + (seq_k - seq_q) >= kpos
        if window is not None:
            mask &= tok + (seq_k - seq_q) - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...][:, 0:1]
        l_prev = l_scr[...][:, 0:1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _done():
        l = l_scr[...][:, 0:1]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, g: int, causal: bool = True,
                    window: int | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (h_K, Nq·g, d); k, v: (h_K, Nk, d). Returns (h_K, Nq·g, d)."""
    h_k, rows_total, d = q.shape
    dv = v.shape[-1]
    seq_k = k.shape[1]
    seq_q = rows_total // g
    block_q = min(block_q, seq_q)
    block_k = min(block_k, seq_k)
    nq = pl.cdiv(seq_q, block_q)
    nk = pl.cdiv(seq_k, block_k)
    rows = block_q * g
    scale = 1.0 / (d ** 0.5)

    # clamp kv index inside the useful band so skipped steps re-touch a
    # resident block (no HBM refetch)
    def kv_index(hk, iq, ik):
        if causal:
            hi = jax.lax.div((iq + 1) * block_q - 1 + (seq_k - seq_q), block_k)
            ik = jnp.minimum(ik, hi)
        if window is not None:
            lo = jnp.maximum(
                (iq * block_q + (seq_k - seq_q) - (window - 1)) // block_k, 0)
            ik = jnp.maximum(ik, lo)
        return (hk, ik, 0)

    kernel = functools.partial(
        _kernel, scale=scale, g=g, block_q=block_q, block_k=block_k,
        seq_q=seq_q, seq_k=seq_k, causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=(h_k, nq, nk),
        in_specs=[
            pl.BlockSpec((1, rows, d), lambda hk, iq, ik: (hk, iq, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, dv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, rows, dv), lambda hk, iq, ik: (hk, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h_k, rows_total, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rows, 128), jnp.float32),
            pltpu.VMEM((rows, 128), jnp.float32),
            pltpu.VMEM((rows, dv), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
