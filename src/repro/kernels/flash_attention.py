"""Pallas TPU flash attention (full-attention baseline + sliding-window branch).

Layouts (GQA folded per KV head):
  q: (h_K, Nq·g, d)  rows are token-major, group-head-minor
  k/v: (h_K, Nk, d)
  out: (h_K, Nq·g, d)

Grid: (h_K, num_q_blocks, num_kv_blocks) — kv innermost (sequential,
"arbitrary"); online-softmax state lives in VMEM scratch across kv steps.
Causal/window-violating kv blocks are skipped with ``pl.when`` and their HBM
fetch elided by clamping the kv index map to the last useful block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *rest, scale, g, block_q, block_k,
            offset, valid_k, causal, window, with_lse=False):
    if with_lse:
        lse_ref, m_scr, l_scr, acc_scr = rest
    else:
        m_scr, l_scr, acc_scr = rest
    iq, ik = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)
    rows = q_ref.shape[1]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # does this kv block intersect the allowed band for this q block?
    q_lo = iq * block_q
    q_hi = q_lo + block_q - 1          # token positions (pre-group-fold)
    k_lo = ik * block_k
    k_hi = k_lo + block_k - 1
    live = k_lo < valid_k
    if causal:
        live &= k_lo <= q_hi + offset
    if window is not None:
        live &= k_hi >= q_lo + offset - (window - 1)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        tok = q_lo + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 0) // g
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 1)
        mask = kpos < valid_k
        if causal:
            mask &= tok + offset >= kpos
        if window is not None:
            mask &= tok + offset - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...][:, 0:1]
        l_prev = l_scr[...][:, 0:1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _done():
        l = l_scr[...][:, 0:1]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
        if with_lse:
            m = m_scr[...][:, 0:1]
            # maskless rows get +inf-like lse so exp(s - lse) -> 0
            lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)),
                            -NEG_INF)
            lse_ref[0] = jnp.broadcast_to(lse, lse_ref.shape[1:])


def _kv_band(block_q, block_k, offset, causal, window):
    """kv index-map clamp: keep skipped steps on a resident block (no HBM
    refetch).  Shared by the forward and the dQ backward (same loop order)."""

    def kv_index(hk, iq, ik, *_):
        if causal:
            hi = jnp.maximum(
                jax.lax.div((iq + 1) * block_q - 1 + offset, block_k), 0)
            ik = jnp.minimum(ik, hi)
        if window is not None:
            lo = jnp.maximum(
                (iq * block_q + offset - (window - 1)) // block_k, 0)
            ik = jnp.maximum(ik, lo)
        return (hk, ik, 0)

    return kv_index


def flash_attention(q, k, v, *, g: int, causal: bool = True,
                    window: int | None = None, block_q: int = 128,
                    block_k: int = 128, valid_k: int | None = None,
                    offset: int | None = None, interpret: bool = True,
                    return_lse: bool = False):
    """q: (h_K, Nq·g, d); k, v: (h_K, Nk, d). Returns (h_K, Nq·g, d).

    ``valid_k`` is the logical key count when k/v carry padding rows (keys at
    positions >= valid_k are masked out; defaults to the array length).
    ``offset`` aligns query token i with key position i + offset for the
    causal/window bands; it defaults to end-alignment of the *arrays*
    (Nk - Nq) — callers padding q and k by different amounts pass the
    logical offset explicitly.  ``return_lse=True`` also returns the per-row
    log-sum-exp (h_K, Nq·g, 128) float32 — the fused-backward residual."""
    h_k, rows_total, d = q.shape
    dv = v.shape[-1]
    seq_k = k.shape[1]
    seq_q = rows_total // g
    valid_k = seq_k if valid_k is None else valid_k
    offset = seq_k - seq_q if offset is None else offset
    block_q = min(block_q, seq_q)
    block_k = min(block_k, seq_k)
    nq = pl.cdiv(seq_q, block_q)
    nk = pl.cdiv(seq_k, block_k)
    rows = block_q * g
    scale = 1.0 / (d ** 0.5)

    kv_index = _kv_band(block_q, block_k, offset, causal, window)

    kernel = functools.partial(
        _kernel, scale=scale, g=g, block_q=block_q, block_k=block_k,
        offset=offset, valid_k=valid_k, causal=causal,
        window=window, with_lse=return_lse)
    out_specs = [pl.BlockSpec((1, rows, dv), lambda hk, iq, ik: (hk, iq, 0))]
    out_shape = [jax.ShapeDtypeStruct((h_k, rows_total, dv), q.dtype)]
    if return_lse:
        out_specs.append(
            pl.BlockSpec((1, rows, 128), lambda hk, iq, ik: (hk, iq, 0)))
        out_shape.append(
            jax.ShapeDtypeStruct((h_k, rows_total, 128), jnp.float32))
    with jax.named_scope("flash_attention"):
        return pl.pallas_call(
            kernel,
            grid=(h_k, nq, nk),
            in_specs=[
                pl.BlockSpec((1, rows, d), lambda hk, iq, ik: (hk, iq, 0)),
                pl.BlockSpec((1, block_k, d), kv_index),
                pl.BlockSpec((1, block_k, dv), kv_index),
            ],
            out_specs=out_specs if return_lse else out_specs[0],
            out_shape=out_shape if return_lse else out_shape[0],
            scratch_shapes=[
                pltpu.VMEM((rows, 128), jnp.float32),
                pltpu.VMEM((rows, 128), jnp.float32),
                pltpu.VMEM((rows, dv), jnp.float32),
            ],
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(q, k, v)


# =====================================================================
# fused backward (flash recurrence: p recomputed from saved out/lse)
#
#   p  = exp(s - lse)              dp = dO · Vᵀ
#   ds = p ∘ (dp - delta) · scale  delta = rowsum(dO ∘ O)
#   dQ = Σ ds·K    dV = Σ pᵀ·dO    dK = Σ dsᵀ·Q
# =====================================================================
def _band_mask(iq, ik, rows, block_q, block_k, g, offset, valid_k,
               causal, window):
    tok = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 0) // g
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 1)
    mask = kpos < valid_k
    if causal:
        mask &= tok + offset >= kpos
    if window is not None:
        mask &= tok + offset - kpos < window
    return mask


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_scr, *, scale, g, block_q, block_k, offset, valid_k,
               causal, window):
    iq, ik = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)
    rows = q_ref.shape[1]

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo, q_hi = iq * block_q, iq * block_q + block_q - 1
    k_lo, k_hi = ik * block_k, ik * block_k + block_k - 1
    live = k_lo < valid_k
    if causal:
        live &= k_lo <= q_hi + offset
    if window is not None:
        live &= k_hi >= q_lo + offset - (window - 1)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _band_mask(iq, ik, rows, block_q, block_k, g, offset,
                          valid_k, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse_ref[0][:, 0:1]), 0.0)
        do = do_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, 0:1]) * scale
        acc_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _done():
        dq_ref[0] = acc_scr[...]


def flash_attention_dq(q, k, v, do, lse, delta, *, g: int, causal: bool = True,
                       window: int | None = None, block_q: int = 128,
                       block_k: int = 128, valid_k: int | None = None,
                       offset: int | None = None, interpret: bool = True):
    """dQ in the forward loop order (grid (h_K, q-blocks, kv-blocks)).
    Returns (h_K, Nq·g, d) float32."""
    h_k, rows_total, d = q.shape
    dv = v.shape[-1]
    seq_k = k.shape[1]
    seq_q = rows_total // g
    valid_k = seq_k if valid_k is None else valid_k
    offset = seq_k - seq_q if offset is None else offset
    block_q = min(block_q, seq_q)
    block_k = min(block_k, seq_k)
    nq = pl.cdiv(seq_q, block_q)
    nk = pl.cdiv(seq_k, block_k)
    rows = block_q * g
    scale = 1.0 / (d ** 0.5)

    kv_index = _kv_band(block_q, block_k, offset, causal, window)
    q_index = lambda hk, iq, ik: (hk, iq, 0)
    kernel = functools.partial(
        _dq_kernel, scale=scale, g=g, block_q=block_q, block_k=block_k,
        offset=offset, valid_k=valid_k, causal=causal, window=window)
    with jax.named_scope("flash_attention_dq"):
        return pl.pallas_call(
            kernel,
            grid=(h_k, nq, nk),
            in_specs=[
                pl.BlockSpec((1, rows, d), q_index),
                pl.BlockSpec((1, block_k, d), kv_index),
                pl.BlockSpec((1, block_k, dv), kv_index),
                pl.BlockSpec((1, rows, dv), q_index),
                pl.BlockSpec((1, rows, 128), q_index),
                pl.BlockSpec((1, rows, 128), q_index),
            ],
            out_specs=pl.BlockSpec((1, rows, d), q_index),
            out_shape=jax.ShapeDtypeStruct((h_k, rows_total, d), jnp.float32),
            scratch_shapes=[pltpu.VMEM((rows, d), jnp.float32)],
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(q, k, v, do, lse, delta)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref,
                dv_ref, dk_scr, dv_scr, *, scale, g, block_q, block_k,
                offset, valid_k, causal, window):
    ik, iq = pl.program_id(1), pl.program_id(2)
    nq = pl.num_programs(2)
    rows = q_ref.shape[1]

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_lo, q_hi = iq * block_q, iq * block_q + block_q - 1
    k_lo, k_hi = ik * block_k, ik * block_k + block_k - 1
    live = k_lo < valid_k
    if causal:
        live &= k_lo <= q_hi + offset
    if window is not None:
        live &= k_hi >= q_lo + offset - (window - 1)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _band_mask(iq, ik, rows, block_q, block_k, g, offset,
                          valid_k, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse_ref[0][:, 0:1]), 0.0)
        do = do_ref[0].astype(jnp.float32)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, 0:1]) * scale
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(iq == nq - 1)
    def _done():
        dk_ref[0] = dk_scr[...]
        dv_ref[0] = dv_scr[...]


def flash_attention_dkv(q, k, v, do, lse, delta, *, g: int,
                        causal: bool = True, window: int | None = None,
                        block_q: int = 128, block_k: int = 128,
                        valid_k: int | None = None, offset: int | None = None,
                        interpret: bool = True):
    """dK/dV with kv blocks in the outer (parallel) grid dim — each kv block
    owns its gradient tile, q blocks walk sequentially (mirroring the
    forward's clamp: out-of-band q steps re-touch a resident block).
    Returns (dk, dv): (h_K, Nk, d) / (h_K, Nk, dv) float32."""
    h_k, rows_total, d = q.shape
    dv_dim = v.shape[-1]
    seq_k = k.shape[1]
    seq_q = rows_total // g
    valid_k = seq_k if valid_k is None else valid_k
    offset = seq_k - seq_q if offset is None else offset
    block_q = min(block_q, seq_q)
    block_k = min(block_k, seq_k)
    nq = pl.cdiv(seq_q, block_q)
    nk = pl.cdiv(seq_k, block_k)
    rows = block_q * g
    scale = 1.0 / (d ** 0.5)

    # clamp the q index into the live band for this kv block (the transpose
    # of the forward's kv clamp)
    def q_index(hk, ik, iq):
        if causal:
            lo = jnp.maximum((ik * block_k - offset) // block_q, 0)
            iq = jnp.maximum(iq, lo)
        if window is not None:
            hi = ((ik * block_k + block_k - 1 - offset
                   + window - 1) // block_q)
            iq = jnp.minimum(iq, jnp.maximum(hi, 0))
        return (hk, iq, 0)

    kv_index = lambda hk, ik, iq: (hk, ik, 0)
    kernel = functools.partial(
        _dkv_kernel, scale=scale, g=g, block_q=block_q, block_k=block_k,
        offset=offset, valid_k=valid_k, causal=causal, window=window)
    with jax.named_scope("flash_attention_dkv"):
        return pl.pallas_call(
            kernel,
            grid=(h_k, nk, nq),
            in_specs=[
                pl.BlockSpec((1, rows, d), q_index),
                pl.BlockSpec((1, block_k, d), kv_index),
                pl.BlockSpec((1, block_k, dv_dim), kv_index),
                pl.BlockSpec((1, rows, dv_dim), q_index),
                pl.BlockSpec((1, rows, 128), q_index),
                pl.BlockSpec((1, rows, 128), q_index),
            ],
            out_specs=[
                pl.BlockSpec((1, block_k, d), kv_index),
                pl.BlockSpec((1, block_k, dv_dim), kv_index),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((h_k, nk * block_k, d), jnp.float32),
                jax.ShapeDtypeStruct((h_k, nk * block_k, dv_dim),
                                     jnp.float32),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_k, d), jnp.float32),
                pltpu.VMEM((block_k, dv_dim), jnp.float32),
            ],
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(q, k, v, do, lse, delta)
