"""FSA paper-faithful three-kernel pipeline (GPU structure, block-granular).

Mirrors the published decomposition exactly (DESIGN.md §2, ablation twin of
``fsa_selected.py``):

  1. **online-softmax statistics kernel** — pre-computes per-row log-sum-exp
     over that row's selected blocks, so the main kernel emits final-scaled
     partials (the paper's "decouple online softmax statistics").
  2. **selected-attention kernel** — the paper's loop order: grid walks KV
     blocks in the outer loop, the scalar-prefetched list of query blocks
     attending each KV block (I_i) in the inner loop; partial results go to
     an intermediate buffer ``O_buf`` addressed by the O_i slot mapping —
     no reduction in this kernel (the GPU-atomics-avoidance structure).
     Padded steps are routed to a dump slot (index ``cap``) so no masking of
     stale memory is ever needed.
  3. **reduction kernel** — accumulates the O_buf slots of each query block
     (partials are already normalized by lse, so reduction is a plain sum).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


# ---------------------------------------------------------------- kernel 1
def _stats_kernel(kv_ids, kv_cnt, q_ref, k_ref, sel_ref, lse_ref, m_scr, l_scr,
                  *, scale, g, block_q, block_k, seq_len):
    hk, iq, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    cap = pl.num_programs(2)
    rows = q_ref.shape[1]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    @pl.when(j < kv_cnt[hk, iq])
    def _step():
        blk = kv_ids[hk, iq, j]
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        tok = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 0) // g
        kpos = blk * block_k + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 1)
        picked = jnp.any(sel_ref[0] == blk, axis=1, keepdims=True)
        mask = picked & (tok >= kpos) & (kpos < seq_len)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...][:, 0:1]
        l_prev = l_scr[...][:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_scr[...] = jnp.broadcast_to(
            jnp.exp(m_prev - m_new) * l_prev + jnp.sum(p, 1, keepdims=True),
            l_scr.shape)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(j == cap - 1)
    def _done():
        m = m_scr[...][:, 0:1]
        l = l_scr[...][:, 0:1]
        # rows with no selected keys get +inf-like lse so exp(s - lse) -> 0
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), -NEG_INF)
        lse_ref[0] = jnp.broadcast_to(lse, lse_ref.shape[1:])


# ---------------------------------------------------------------- kernel 2
def _partial_kernel(q_ids, slot_ids, q_cnt, q_ref, k_ref, v_ref, sel_ref,
                    lse_ref, obuf_ref, *, scale, g, block_q, block_k, seq_len):
    hk, ib, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    rows = q_ref.shape[1]
    qb = q_ids[hk, ib, j]

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    tok = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 0) // g
    kpos = ib * block_k + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 1)
    picked = jnp.any(sel_ref[0] == ib, axis=1, keepdims=True)
    mask = picked & (tok >= kpos) & (kpos < seq_len)
    lse = lse_ref[0][:, 0:1]
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)   # final-scaled: no rescale later
    pv = jax.lax.dot_general(p, v_ref[0].astype(jnp.float32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    obuf_ref[0, 0, 0] = pv.astype(obuf_ref.dtype)


# ---------------------------------------------------------------- kernel 3
def _reduce_kernel(kv_cnt, obuf_ref, o_ref, acc_scr):
    hk, iq, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    cap = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j < kv_cnt[hk, iq])
    def _step():
        acc_scr[...] += obuf_ref[0, 0, 0].astype(jnp.float32)

    @pl.when(j == cap - 1)
    def _done():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def fsa_faithful(q_rows, k, v, sel_rows, kv_ids, kv_cnt, q_ids, slot_ids, q_cnt,
                 *, g: int, block_q: int, block_k: int,
                 seq_len: int | None = None, interpret: bool = True,
                 return_lse: bool = False):
    """Three-kernel FSA (paper structure). Same I/O contract as fsa_selected.

    ``return_lse=True`` additionally returns the statistics kernel's per-row
    log-sum-exp (h_K, N·g, 128) float32 — the fused-backward residual (no
    extra compute: kernel 1 produces it anyway)."""
    h_k, rows_total, d = q_rows.shape
    dv = v.shape[-1]
    seq_len = k.shape[1] if seq_len is None else seq_len
    nq, cap = kv_ids.shape[1], kv_ids.shape[2]
    nb, capq = q_ids.shape[1], q_ids.shape[2]
    rows = block_q * g
    t = sel_rows.shape[-1]
    scale = 1.0 / (d ** 0.5)

    # ---- kernel 1: statistics --------------------------------------------
    stats = functools.partial(_stats_kernel, scale=scale, g=g, block_q=block_q,
                              block_k=block_k, seq_len=seq_len)
    with jax.named_scope("fsa_faithful_stats"):
        lse = pl.pallas_call(
            stats,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=2,
                grid=(h_k, nq, cap),
                in_specs=[
                    pl.BlockSpec((1, rows, d),
                                 lambda hk, iq, j, i1, c1: (hk, iq, 0)),
                    pl.BlockSpec((1, block_k, d),
                                 lambda hk, iq, j, i1, c1:
                                     (hk, i1[hk, iq, j], 0)),
                    pl.BlockSpec((1, rows, t),
                                 lambda hk, iq, j, i1, c1: (hk, iq, 0)),
                ],
                out_specs=pl.BlockSpec((1, rows, 128),
                                       lambda hk, iq, j, i1, c1: (hk, iq, 0)),
                scratch_shapes=[pltpu.VMEM((rows, 128), jnp.float32)] * 2,
            ),
            out_shape=jax.ShapeDtypeStruct((h_k, rows_total, 128),
                                           jnp.float32),
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(kv_ids, kv_cnt, q_rows, k, sel_rows)

    # ---- kernel 2: KV-block-major partials into O_buf ---------------------
    partial = functools.partial(_partial_kernel, scale=scale, g=g,
                                block_q=block_q, block_k=block_k, seq_len=seq_len)

    def _obuf_index(hk, ib, j, qi, si, qc):
        # dump slot (cap) for padded steps so valid slots are never clobbered
        slot = jnp.where(j < qc[hk, ib], si[hk, ib, j], cap)
        return (hk, qi[hk, ib, j], slot, 0, 0)

    with jax.named_scope("fsa_faithful_partial"):
        obuf = pl.pallas_call(
            partial,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=3,
                grid=(h_k, nb, capq),
                in_specs=[
                    pl.BlockSpec((1, rows, d),
                                 lambda hk, ib, j, qi, si, qc:
                                     (hk, qi[hk, ib, j], 0)),
                    pl.BlockSpec((1, block_k, d),
                                 lambda hk, ib, j, qi, si, qc: (hk, ib, 0)),
                    pl.BlockSpec((1, block_k, dv),
                                 lambda hk, ib, j, qi, si, qc: (hk, ib, 0)),
                    pl.BlockSpec((1, rows, t),
                                 lambda hk, ib, j, qi, si, qc:
                                     (hk, qi[hk, ib, j], 0)),
                    pl.BlockSpec((1, rows, 128),
                                 lambda hk, ib, j, qi, si, qc:
                                     (hk, qi[hk, ib, j], 0)),
                ],
                out_specs=pl.BlockSpec((1, 1, 1, rows, dv), _obuf_index),
            ),
            out_shape=jax.ShapeDtypeStruct((h_k, nq, cap + 1, rows, dv),
                                           jnp.float32),
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "arbitrary", "arbitrary")),
            interpret=interpret,
        )(q_ids, slot_ids, q_cnt, q_rows, k, v, sel_rows, lse)

    # ---- kernel 3: reduction ----------------------------------------------
    with jax.named_scope("fsa_faithful_reduce"):
        out = pl.pallas_call(
            _reduce_kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(h_k, nq, cap),
                in_specs=[
                    pl.BlockSpec((1, 1, 1, rows, dv),
                                 lambda hk, iq, j, c1: (hk, iq, j, 0, 0)),
                ],
                out_specs=pl.BlockSpec((1, rows, dv),
                                       lambda hk, iq, j, c1: (hk, iq, 0)),
                scratch_shapes=[pltpu.VMEM((rows, dv), jnp.float32)],
            ),
            out_shape=jax.ShapeDtypeStruct((h_k, rows_total, dv),
                                           q_rows.dtype),
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(kv_cnt, obuf)
    return (out, lse) if return_lse else out
