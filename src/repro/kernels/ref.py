"""Pure-jnp oracles for every Pallas kernel, in kernel layouts.

These delegate to the dense-mask references in ``repro.core.reference`` and
are the assert_allclose targets of the kernel test sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import reference
from repro.core.nsa_config import NSAConfig


def rows_from_heads(q: jnp.ndarray, h_k: int) -> jnp.ndarray:
    """(N, h, d) -> (h_K, N·g, d), token-major group-head-minor rows."""
    n, h, d = q.shape
    g = h // h_k
    return q.reshape(n, h_k, g, d).transpose(1, 0, 2, 3).reshape(h_k, n * g, d)


def heads_from_rows(o: jnp.ndarray, n: int) -> jnp.ndarray:
    """(h_K, N·g, d) -> (N, h, d)."""
    h_k, rows, d = o.shape
    g = rows // n
    return o.reshape(h_k, n, g, d).transpose(1, 0, 2, 3).reshape(n, h_k * g, d)


def selected_ref(q, k, v, idx, valid, cfg: NSAConfig):
    """Oracle for the selected branch. q: (N,h,d), k/v: (N,h_K,d)."""
    out, _ = reference.selected_attention_ref(q, k, v, idx, valid, cfg)
    return out


def flash_ref(q, k, v, *, causal=True, window=None):
    """Oracle for the flash kernel. q: (N,h,d), k/v: (S,h_K,d)."""
    if window is not None:
        return reference.sliding_attention_ref(q, k, v, window)
    return reference.full_attention_ref(q, k, v, causal=causal)


def flash_ref_chunked(q, k, v, *, causal=True, window=None, q_chunk=512):
    """Memory-bounded oracle (lax.map over query chunks) — used as the
    differentiable body behind the kernels' custom-VJP backward pass."""
    n, h, d = q.shape
    s = k.shape[0]
    c = min(q_chunk, n)
    pad = (c - n % c) % c
    qp = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))

    def body(args):
        q_c, c0 = args
        pos_q = jnp.arange(c) + c0 + (s - n)
        scores = reference._gqa_scores(q_c, k)
        mask = jnp.ones((c, s), bool) if not causal else (
            pos_q[:, None] >= jnp.arange(s)[None, :])
        if window is not None:
            mask &= pos_q[:, None] - jnp.arange(s)[None, :] < window
        probs, _ = reference._safe_softmax(scores, mask[:, None, :])
        return reference._gqa_out(probs, v).astype(q.dtype)

    starts = jnp.arange(0, n + pad, c)
    out = jax.lax.map(body, (qp.reshape(-1, c, h, d), starts))
    return out.reshape(-1, h, v.shape[-1])[:n]
