"""Jit'd dispatch wrappers around the Pallas kernels.

``selected_attention`` is the public entry for the paper's bottleneck branch;
``cfg.kernel`` picks the implementation:

  fsa           — FSA-TPU kernel (production; DESIGN.md §2)
  fsa_faithful  — paper-structure three-kernel pipeline (ablation)
  nsa           — vanilla-NSA-style baseline kernel (g padded to 8)
  reference     — dense-mask oracle

Forward runs the kernel; backward is a custom VJP through the sparse
gather formulation (identical math, XLA-differentiable) — on-TPU backward
kernels are a recorded extension (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import indexing, sparse
from repro.core.paging import gather_rows
from repro.core.nsa_config import NSAConfig
from repro.kernels import flash_attention as _flash
from repro.kernels import fsa_faithful as _faithful
from repro.kernels import fsa_selected as _fsa
from repro.kernels import nsa_selected as _nsa
from repro.kernels import paged_decode as _paged
from repro.kernels import ref as _ref


def _pad_tokens(x, n_pad):
    return jnp.pad(x, ((0, n_pad - x.shape[0]),) + ((0, 0),) * (x.ndim - 1))


def _selected_fwd_impl(q, k, v, idx, valid, cfg: NSAConfig):
    n, h, d = q.shape
    h_k = k.shape[1]
    g = h // h_k
    bq = min(cfg.q_block_size, max(8, n))
    n_pad = ((n + bq - 1) // bq) * bq

    qp = _pad_tokens(q, n_pad)
    idxp = _pad_tokens(idx, n_pad)
    validp = _pad_tokens(valid, n_pad)
    # normalize: ascending sort, duplicates invalidated (top-k selection never
    # produces dups, but the kernel contract must not depend on that)
    key = jnp.where(validp, idxp, jnp.iinfo(jnp.int32).max // 2)
    order = jnp.argsort(key, axis=-1)
    idxp = jnp.take_along_axis(idxp, order, axis=-1)
    validp = jnp.take_along_axis(validp, order, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros_like(validp[..., :1]),
         (idxp[..., 1:] == idxp[..., :-1]) & validp[..., 1:] & validp[..., :-1]],
        axis=-1)
    validp &= ~dup
    sel = jnp.where(validp, idxp, -1).astype(jnp.int32)       # (N, h_K, T)
    # rows layout for sel: repeat each token's list over the g group heads
    sel_rows = jnp.repeat(sel.transpose(1, 0, 2), g, axis=1)  # (h_K, N·g, T)
    q_rows = _ref.rows_from_heads(qp, h_k)
    k_t = k.transpose(1, 0, 2)
    v_t = v.transpose(1, 0, 2)

    if cfg.kernel == "nsa":
        g_pad = max(g, 8)
        q_pad = qp.reshape(n_pad, h_k, g, d).transpose(1, 0, 2, 3)
        q_pad = jnp.pad(q_pad, ((0, 0), (0, 0), (0, g_pad - g), (0, 0)))
        o = _nsa.nsa_selected(q_pad, k_t, v_t, sel.transpose(1, 0, 2),
                              block_k=cfg.block_size, interpret=cfg.interpret)
        o = o[:, :, :g].transpose(1, 0, 2, 3).reshape(n_pad, h, -1)
        return o[:n]

    kv_ids, kv_cnt = indexing.build_qblock_union(idxp, validp, cfg, k.shape[0])
    if cfg.kernel == "fsa":
        o_rows = _fsa.fsa_selected(q_rows, k_t, v_t, sel_rows, kv_ids, kv_cnt,
                                   g=g, block_q=bq, block_k=cfg.block_size,
                                   interpret=cfg.interpret)
    elif cfg.kernel == "fsa_faithful":
        q_ids, slot_ids, q_cnt = indexing.build_kvblock_qlists(
            idxp, validp, cfg, k.shape[0], union_cap=kv_ids.shape[-1])
        o_rows = _faithful.fsa_faithful(q_rows, k_t, v_t, sel_rows, kv_ids,
                                        kv_cnt, q_ids, slot_ids, q_cnt, g=g,
                                        block_q=bq, block_k=cfg.block_size,
                                        interpret=cfg.interpret)
    elif cfg.kernel == "reference":
        return _ref.selected_ref(q, k, v, idx, valid, cfg)
    else:
        raise ValueError(f"unknown kernel: {cfg.kernel}")
    return _ref.heads_from_rows(o_rows, n_pad)[:n]


def _selected_sparse(q, k, v, idx, valid, cfg: NSAConfig):
    """Differentiable twin of the kernel (chunked gather path)."""
    n = q.shape[0]
    c = min(512, n)
    pad = (c - n % c) % c
    qp, idxp, validp = (_pad_tokens(a, n + pad) for a in (q, idx, valid))

    def body(args):
        q_c, i_c, v_c, pos_c = args
        return sparse.selected_gather_attention(q_c, k, v, i_c, v_c, cfg, pos_c)

    nc = (n + pad) // c
    out = jax.lax.map(body, (qp.reshape(nc, c, *q.shape[1:]),
                             idxp.reshape(nc, c, *idx.shape[1:]),
                             validp.reshape(nc, c, *valid.shape[1:]),
                             jnp.arange(n + pad).reshape(nc, c)))
    return out.reshape(n + pad, q.shape[1], -1)[:n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def selected_attention(q, k, v, idx, valid, cfg: NSAConfig):
    """Selected-branch attention. q: (N,h,d), k/v: (S,h_K,d), idx/valid: (N,h_K,T)."""
    return _selected_fwd_impl(q, k, v, idx, valid, cfg)


def _sel_fwd(q, k, v, idx, valid, cfg):
    return _selected_fwd_impl(q, k, v, idx, valid, cfg), (q, k, v, idx, valid)


def _sel_bwd(cfg, res, dout):
    q, k, v, idx, valid = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _selected_sparse(q_, k_, v_, idx, valid, cfg),
                     q, k, v)
    dq, dk, dv = vjp(dout)
    zi = jnp.zeros(idx.shape, jax.dtypes.float0)
    zv = jnp.zeros(valid.shape, jax.dtypes.float0)
    return dq, dk, dv, zi, zv


selected_attention.defvjp(_sel_fwd, _sel_bwd)


def _flash_fwd_impl(q, k, v, cfg: NSAConfig, causal, window):
    n, h, d = q.shape
    h_k = k.shape[1]
    g = h // h_k
    bq = min(cfg.q_block_size, max(8, n))
    n_pad = ((n + bq - 1) // bq) * bq
    q_rows = _ref.rows_from_heads(_pad_tokens(q, n_pad), h_k)
    o_rows = _flash.flash_attention(
        q_rows, k.transpose(1, 0, 2), v.transpose(1, 0, 2), g=g, causal=causal,
        window=window, block_q=bq, block_k=min(128, k.shape[0]),
        interpret=cfg.interpret)
    return _ref.heads_from_rows(o_rows, n_pad)[:n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_op(q, k, v, cfg, causal, window):
    return _flash_fwd_impl(q, k, v, cfg, causal, window)


def _flash_fwd(q, k, v, cfg, causal, window):
    return _flash_fwd_impl(q, k, v, cfg, causal, window), (q, k, v)


def _flash_bwd(cfg, causal, window, res, dout):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.flash_ref_chunked(q_, k_, v_, causal=causal,
                                                  window=window), q, k, v)
    return vjp(dout)


_flash_op.defvjp(_flash_fwd, _flash_bwd)


def _paged_sel_win_ref(q, k_pages, v_pages, page_table, idx, valid, pos,
                       cfg: NSAConfig):
    """Gather-through-page-table reference for ONE slot's selected + sliding
    branches.  q: (h, d); idx/valid: (h_k, T); pos: scalar.
    Returns (out_sel, out_win): each (h, dv) float32.
    """
    from repro.core.reference import _gqa_out, _gqa_scores, _safe_softmax

    h, d = q.shape
    p_sz, h_k = k_pages.shape[1], k_pages.shape[2]
    g = h // h_k

    # --- selected branch: gather exactly the T physical pages per KV head
    #     (each head pulls only its own rows of its own pages) ---
    t = idx.shape[-1]
    phys = page_table[idx]                                  # (h_k, T)
    hk_i = jnp.arange(h_k)
    k_sel = jax.vmap(lambda ph, i: k_pages[ph, :, i])(phys, hk_i)
    v_sel = jax.vmap(lambda ph, i: v_pages[ph, :, i])(phys, hk_i)
    k_sel = k_sel.reshape(h_k, t * p_sz, d)                 # (h_k, T·P, d)
    v_sel = v_sel.reshape(h_k, t * p_sz, -1)
    tok_pos = (idx[..., None] * p_sz + jnp.arange(p_sz)).reshape(h_k, t * p_sz)
    sel_mask = jnp.repeat(valid, p_sz, axis=-1) & (tok_pos <= pos)
    qg = q.reshape(h_k, g, d).astype(jnp.float32)
    s_sel = jnp.einsum("kgd,ksd->kgs", qg, k_sel.astype(jnp.float32))
    s_sel = s_sel / jnp.sqrt(d).astype(jnp.float32)
    p_sel, _ = _safe_softmax(s_sel, sel_mask[:, None, :])
    out_sel = jnp.einsum("kgs,ksd->kgd", p_sel, v_sel.astype(jnp.float32))

    # --- sliding branch: the trailing window through the page table ---
    w = cfg.window_size
    win_rows = pos - (w - 1) + jnp.arange(w)
    k_win = gather_rows(k_pages, page_table, win_rows)      # (W, h_k, d)
    v_win = gather_rows(v_pages, page_table, win_rows)
    win_mask = (win_rows >= 0) & (win_rows <= pos)
    p_win, _ = _safe_softmax(_gqa_scores(q[None], k_win),
                             win_mask[None, None, :])
    out_win = _gqa_out(p_win, v_win)[0]
    return out_sel.reshape(h, -1), out_win


def paged_decode_attention_batched(gates, q, k_pages, v_pages, page_tables,
                                   cmp_k, cmp_v, pos, cfg: NSAConfig, *,
                                   use_kernel: bool = False,
                                   block_s: int | None = None):
    """Batched multi-slot NSA decode reading KV through per-slot page tables —
    touches ONLY the pages the three branches address (page size == B_K, so
    one selected block is one physical page):

      compressed  all compressed-token rows (already gathered views — they
                  are O(N/stride) small)
      selected    the T pages named by ``page_table[idx]`` per slot
      sliding     the trailing ceil(W/B_K)+1 pages per slot

    gates: (B, h, 3); q: (B, h, d); k_pages/v_pages: (N_pages, P, h_k, d*);
    page_tables: (B, max_pages) int32; cmp_k/cmp_v: (B, N_cmp_max, h_k, d*);
    pos: (B,).  Returns (B, h, dv).

    ``use_kernel=True`` runs the Pallas paged-decode kernel: ``fsa_selected``'s
    BlockSpec pattern with the kv index_map composed through the page table
    (ids -> page_table[ids]) and B slots folded into the matmul M dimension —
    one launch per engine tick.  ``use_kernel=False`` is the gather reference
    (still a single batched dispatch, vmapped over slots).  The compressed
    prologue is shared with the dense-cache decode via
    ``sparse.decode_cmp_and_select`` on both paths.
    """
    b, h, d = q.shape
    p_sz, h_k = k_pages.shape[1], k_pages.shape[2]
    assert p_sz == cfg.block_size, "page size must equal the NSA block size"
    g = h // h_k
    s_max = page_tables.shape[1] * p_sz

    # --- compressed branch + top-T selection (shared with the dense path;
    #     logical block id == page-table index) ---
    out_cmp, idx, valid = jax.vmap(
        lambda q1, ck, cv, p1: sparse.decode_cmp_and_select(
            q1[None], ck, cv, p1, cfg, s_max))(q, cmp_k, cmp_v, pos)
    out_cmp = out_cmp[:, 0]                                  # (B, h, dv)
    idx, valid = idx[:, 0], valid[:, 0]                      # (B, h_k, T)

    if use_kernel:
        bs = block_s or cfg.paged_slot_block or max(1, -(-8 // g))
        bs = min(bs, b)
        pad = (-b) % bs
        if pad:
            q_p = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
            tables_p = jnp.pad(page_tables, ((0, pad), (0, 0)))
            idx_p = jnp.pad(idx, ((0, pad), (0, 0), (0, 0)))
            valid_p = jnp.pad(valid, ((0, pad), (0, 0), (0, 0)))
            pos_p = jnp.pad(pos, ((0, pad),))
        else:
            q_p, tables_p, idx_p, valid_p, pos_p = (q, page_tables, idx,
                                                    valid, pos)
        bp = b + pad
        pages, blks = _paged.build_decode_steps(
            idx_p, valid_p, tables_p, pos_p, window=cfg.window_size,
            page_size=p_sz, block_s=bs)
        q_rows = (q_p.reshape(bp, h_k, g, d).transpose(1, 0, 2, 3)
                     .reshape(h_k, bp * g, d))
        o_sel, o_win = _paged.paged_decode(
            q_rows, k_pages, v_pages, pages, blks, pos_p.astype(jnp.int32),
            g=g, block_s=bs, num_sel=idx.shape[-1], window=cfg.window_size,
            interpret=cfg.interpret)
        dv = o_sel.shape[-1]
        unfold = lambda o: (o.reshape(h_k, bp, g, dv).transpose(1, 0, 2, 3)
                             .reshape(bp, h, dv)[:b])
        out_sel, out_win = unfold(o_sel), unfold(o_win)
    else:
        out_sel, out_win = jax.vmap(
            lambda q1, tb, i1, v1, p1: _paged_sel_win_ref(
                q1, k_pages, v_pages, tb, i1, v1, p1, cfg))(
                    q, page_tables, idx, valid, pos)

    gf = gates.astype(jnp.float32)
    out = (gf[..., 0:1] * out_cmp.astype(jnp.float32)
           + gf[..., 1:2] * out_sel
           + gf[..., 2:3] * out_win)
    return out.astype(q.dtype)


def paged_decode_attention(gates, q, k_pages, v_pages, page_table,
                           cmp_k, cmp_v, pos, cfg: NSAConfig, *,
                           use_kernel: bool = False):
    """One-token (single-slot) NSA paged decode; see
    ``paged_decode_attention_batched`` for the semantics.  q: (h, d);
    page_table: (max_pages,); cmp_k/cmp_v: (N_cmp_max, h_k, d*); pos: scalar.
    """
    return paged_decode_attention_batched(
        gates[None], q[None], k_pages, v_pages, page_table[None],
        cmp_k[None], cmp_v[None], pos[None], cfg, use_kernel=use_kernel)[0]


def full_attention(q, k, v, cfg: NSAConfig, *, causal: bool = True):
    """Flash full attention. q: (N,h,d), k/v: (S,h_K,d)."""
    return _flash_op(q, k, v, cfg, causal, None)


def sliding_attention(q, k, v, window: int, cfg: NSAConfig):
    """Flash sliding-window attention (causal)."""
    return _flash_op(q, k, v, cfg, True, window)
