"""Compatibility facade over ``repro.attention`` (the unified dispatch API).

The kernel *implementations* live in this package (``fsa_selected``,
``fsa_faithful``, ``nsa_selected``, ``flash_attention``, ``paged_decode``);
the *dispatch* — which organization runs for which request — lives in
``repro.attention`` (capability-based backend registry, see README
"Attention API").  This module keeps the historical entry points working:

  selected_attention   — selected branch via the policy's Pallas kernel
                         (fsa | fsa_faithful | nsa | reference)
  full_attention / sliding_attention — Pallas flash wrappers
  paged_decode_attention(_batched)   — paged serving decode; ``backend=``
                         picks the registry backend (``paged_kernel`` |
                         ``paged_gather``; default: the gather reference)

Forward runs the kernel; backward goes through the shared custom-VJP
scaffolding in ``repro.attention.vjp`` — fused Pallas backward kernels
(``fsa_selected_bwd``, the flash dq/dkv kernels) for the backends that
declare ``fused_backward``, the differentiable sparse-gather twin
(identical math, XLA-differentiable) for the rest.
"""
from __future__ import annotations

from repro.core.nsa_config import NSAConfig


def selected_attention(q, k, v, idx, valid, cfg: NSAConfig):
    """Selected-branch attention. q: (N,h,d), k/v: (S,h_K,d), idx/valid:
    (N,h_K,T).  The Pallas kernel is picked by ``cfg.policy.backend``."""
    from repro import attention as uattn

    return uattn.selected_attention(q, k, v, idx, valid, cfg)


def full_attention(q, k, v, cfg: NSAConfig, *, causal: bool = True):
    """Flash full attention. q: (N,h,d), k/v: (S,h_K,d)."""
    from repro import attention as uattn

    return uattn.flash_attention(q, k, v, cfg, causal=causal, window=None)


def sliding_attention(q, k, v, window: int, cfg: NSAConfig):
    """Flash sliding-window attention (causal)."""
    from repro import attention as uattn

    return uattn.flash_attention(q, k, v, cfg, causal=True, window=window)


def paged_decode_attention_batched(gates, q, k_pages, v_pages, page_tables,
                                   cmp_k, cmp_v, pos, cfg: NSAConfig, *,
                                   backend: str | None = None,
                                   block_s: int | None = None):
    """Batched multi-slot NSA paged decode (compat wrapper; see
    ``repro.attention.backends.paged_decode_attention`` for the semantics).

    gates: (B, h, 3); q: (B, h, d); k_pages/v_pages: (N_pages, P, h_k, d*);
    page_tables: (B, max_pages) int32; cmp_k/cmp_v: (B, N_cmp_max, h_k, d*);
    pos: (B,).  Returns (B, h, dv).
    """
    from repro import attention as uattn

    # historical default of this wrapper: the gather reference
    name = backend if backend is not None else "paged_gather"
    cache = {"page_tables": page_tables, "cmp_k": cmp_k, "cmp_v": cmp_v,
             "pos": pos}
    return uattn.nsa_attention(None, gates, q, k_pages, v_pages, cache,
                               cfg=cfg, mode="paged_decode", backend=name,
                               block_s=block_s)


def paged_decode_attention(gates, q, k_pages, v_pages, page_table,
                           cmp_k, cmp_v, pos, cfg: NSAConfig, *,
                           backend: str | None = None,
                           block_s: int | None = None):
    """One-token (single-slot) NSA paged decode; see
    ``paged_decode_attention_batched`` for the semantics.  q: (h, d);
    page_table: (max_pages,); cmp_k/cmp_v: (N_cmp_max, h_k, d*); pos: scalar.
    """
    return paged_decode_attention_batched(
        gates[None], q[None], k_pages, v_pages, page_table[None],
        cmp_k[None], cmp_v[None], pos[None], cfg, backend=backend,
        block_s=block_s)[0]
