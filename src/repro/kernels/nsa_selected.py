"""NSA selected-attention baseline kernel (the design FSA improves upon).

Faithful to the vanilla NSA loop order: grid walks *query tokens* (outer) and
the token's T selected KV blocks (inner).  The g query heads sharing a KV head
form the matmul M dimension, padded to the hardware minimum (8 sublanes on
TPU, mirroring the ≥8 PTX mma constraint on Hopper) — the padding waste that
FSA eliminates.  Kept as a first-class baseline for the paper's comparisons.

Layouts:
  q:   (h_K, N, g_pad, d)  (g rows valid, padded to g_pad = max(g, 8))
  k/v: (h_K, N, d)
  idx: (h_K, N, T) int32 (-1 invalid)  — scalar prefetch
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, g_pad, block_k, seq_len):
    hk, t, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    t_sel = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    blk = idx_ref[hk, t, j]

    @pl.when(blk >= 0)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)        # (g_pad, d)
        k = k_ref[0].astype(jnp.float32)           # (B_K, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = blk * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (g_pad, block_k), 1)
        mask = (kpos <= t) & (kpos < seq_len)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...][:, 0:1]
        l_prev = l_scr[...][:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        pv = jax.lax.dot_general(p, v_ref[0].astype(jnp.float32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        l_scr[...] = jnp.broadcast_to(corr * l_prev + jnp.sum(p, 1, keepdims=True),
                                      l_scr.shape)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(j == t_sel - 1)
    def _done():
        l = l_scr[...][:, 0:1]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def nsa_selected(q_pad, k, v, idx, *, block_k: int,
                 seq_len: int | None = None, interpret: bool = True):
    """q_pad: (h_K, N, g_pad, d); idx: (h_K, N, T). Returns like q_pad.

    ``seq_len`` is the logical key count when k/v carry padding rows up to a
    whole number of KV blocks (defaults to the array length)."""
    h_k, n, g_pad, d = q_pad.shape
    dv = v.shape[-1]
    t_sel = idx.shape[-1]
    seq_len = k.shape[1] if seq_len is None else seq_len
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_kernel, scale=scale, g_pad=g_pad,
                               block_k=block_k, seq_len=seq_len)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(h_k, n, t_sel),
        in_specs=[
            pl.BlockSpec((1, 1, g_pad, d), lambda hk, t, j, ids: (hk, t, 0, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda hk, t, j, ids: (hk, jnp.maximum(ids[hk, t, j], 0), 0)),
            pl.BlockSpec((1, block_k, dv),
                         lambda hk, t, j, ids: (hk, jnp.maximum(ids[hk, t, j], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g_pad, dv), lambda hk, t, j, ids: (hk, t, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g_pad, 128), jnp.float32),
            pltpu.VMEM((g_pad, 128), jnp.float32),
            pltpu.VMEM((g_pad, dv), jnp.float32),
        ],
    )
    with jax.named_scope("nsa_selected"):
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((h_k, n, g_pad, dv), q_pad.dtype),
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "arbitrary", "arbitrary")),
            interpret=interpret,
        )(idx, q_pad, k, v)
