"""Pallas paged-decode kernel: batched multi-slot NSA decode through a page
table.

Decode is the serving hot path: every engine tick produces ONE query token
per active slot.  A single slot's query is g (< 8) rows — far below the MXU's
M = 128 — so, exactly as FSA fills the M dimension with query *tokens* that
share a KV block, this kernel fills it with *slots*: the q layout is
(h_K, B·g, d) and a block of ``block_s`` slots is folded into one M dim of
``block_s·g`` rows.  One kernel launch serves the whole batch (O(1) dispatch
per engine tick instead of O(batch)).

Page-table composition (the ``fsa_selected`` BlockSpec pattern, one level
deeper): ``fsa_selected`` prefetches a union list of *logical* KV block ids
and its kv index_map reads ``ids[hk, iq, j]``.  Here the logical ids are
first translated through the slot's page table on the host side of the
launch (``phys = page_table[ids]``), and the kv index_map reads the
*physical* page id — so the kernel touches exactly the pages the NSA
branches address, at page granularity, with zero gather traffic outside the
selected pages (page size == B_K: one selected block IS one physical page).

Grid = (h_K, num_slot_blocks, union_step):
  the two outer dims are core-parallel; the inner dim walks, slot-major, the
  per-slot step list
      [T selected pages] ++ [ceil(W/P)+1 trailing sliding-window pages]
  so step j belongs to slot ``j // steps_per_slot`` of the block and is a
  selected-branch step iff ``j % steps_per_slot < T`` (both decodable from j
  alone — no prefetched metadata needed for the schedule itself).

The selected and sliding branches are *separate softmaxes* in NSA, so the
kernel keeps two online-softmax states in VMEM scratch and emits two outputs;
the compressed branch is O(N/stride) small and stays outside (shared with the
dense-cache decode via ``sparse.decode_cmp_and_select``), as does the gate
combination.  Rows of slots other than the step's slot (and steps whose
logical block id is -1: invalid selection slots, pre-sequence window pages,
idle padding slots) are masked, which leaves their softmax state untouched.

Inputs (layouts produced by ``ops.paged_decode_attention_batched``):
  q_rows:      (h_K, B·g, d)     slot-major, group-head-minor rows
  k/v_pages:   (N_pages, P, h_K, d*)  the shared paged pools
  pages:       (h_K, nsb, S)     scalar-prefetch: physical page per step
  blks:        (h_K, nsb, S)     scalar-prefetch: logical block id (-1 pad)
  pos:         (B,)              scalar-prefetch: per-slot absolute position
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def num_window_pages(window: int, page_size: int) -> int:
    """Trailing pages that can overlap a W-token sliding window."""
    return -(-window // page_size) + 1


def _kernel(pages, blks, pos, q_ref, k_ref, v_ref, o_sel_ref, o_win_ref,
            m_scr, l_scr, acc_scr, *, scale, g, block_s, page_size, window,
            num_sel, steps_per_slot):
    hk, sb, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    total_steps = pl.num_programs(2)
    rows = q_ref.shape[1]                       # block_s · g

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # the schedule is decodable from j alone (slot-major step layout)
    slot = j // steps_per_slot                  # slot within this slot block
    is_sel = (j % steps_per_slot) < num_sel     # else: sliding-window step
    blk = blks[hk, sb, j]
    p = pos[sb * block_s + slot]

    q = q_ref[0].astype(jnp.float32)                          # (rows, d)
    k = k_ref[:, :, 0, :].reshape(page_size, -1).astype(jnp.float32)
    v = v_ref[:, :, 0, :].reshape(page_size, -1).astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    row_slot = jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 0) // g
    kpos = blk * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (rows, page_size), 1)
    mask = (row_slot == slot) & (blk >= 0) & (kpos <= p)
    mask &= jnp.where(is_sel, True, kpos > p - window)
    s = jnp.where(mask, s, NEG_INF)

    def _accum(b):
        """Online-softmax update of branch b's state (0 = sel, 1 = win)."""
        m_prev = m_scr[b][:, 0:1]
        l_prev = l_scr[b][:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        pr = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        pv = jax.lax.dot_general(pr, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[b] = acc_scr[b] * corr + pv
        l_scr[b] = jnp.broadcast_to(corr * l_prev + jnp.sum(pr, 1, keepdims=True),
                                    l_scr[b].shape)
        m_scr[b] = jnp.broadcast_to(m_new, m_scr[b].shape)

    @pl.when(is_sel)
    def _sel_step():
        _accum(0)

    @pl.when(jnp.logical_not(is_sel))
    def _win_step():
        _accum(1)

    @pl.when(j == total_steps - 1)
    def _done():
        o_sel_ref[0] = (acc_scr[0] / jnp.maximum(l_scr[0][:, 0:1], 1e-30)
                        ).astype(o_sel_ref.dtype)
        o_win_ref[0] = (acc_scr[1] / jnp.maximum(l_scr[1][:, 0:1], 1e-30)
                        ).astype(o_win_ref.dtype)


def build_decode_steps(idx, valid, page_tables, pos, *, window: int,
                       page_size: int, block_s: int):
    """Device-side step-list construction for the kernel.

    idx/valid: (B, h_K, T) per-slot selected logical blocks; page_tables:
    (B, max_pages); pos: (B,).  B must already be padded to a multiple of
    ``block_s`` (padding slots: valid all-False, pos 0, table all dump-page).

    Returns (pages, blks): both (h_K, nsb, block_s · steps_per_slot) int32,
    slot-major along the last dim; blk == -1 marks masked steps.
    """
    b, h_k, t = idx.shape
    max_pages = page_tables.shape[1]
    n_win = num_window_pages(window, page_size)

    blk_sel = jnp.where(valid, idx, -1)                        # (B, h_K, T)
    last = pos // page_size                                    # (B,)
    first = jnp.maximum((pos - window + 1) // page_size, 0)
    wb = last[:, None] - jnp.arange(n_win)[None, :]            # (B, n_win)
    blk_win = jnp.where(wb >= first[:, None], wb, -1)
    blk_win = jnp.broadcast_to(blk_win[:, None, :], (b, h_k, n_win))
    blk_all = jnp.concatenate([blk_sel, blk_win], axis=-1)     # (B, h_K, sps)

    safe = jnp.clip(blk_all, 0, max_pages - 1)
    phys = jnp.take_along_axis(
        page_tables[:, None, :], safe.reshape(b, -1)[:, None, :], axis=2)
    phys = jnp.where(blk_all >= 0, phys.reshape(blk_all.shape), 0)

    def fold(a):  # (B, h_K, sps) -> (h_K, nsb, block_s·sps)
        return (a.transpose(1, 0, 2)
                 .reshape(h_k, b // block_s, block_s * a.shape[-1]))

    return fold(phys.astype(jnp.int32)), fold(blk_all.astype(jnp.int32))


def paged_decode(q_rows, k_pages, v_pages, pages, blks, pos, *, g: int,
                 block_s: int, num_sel: int, window: int,
                 interpret: bool = True):
    """Selected + sliding branch attention over paged KV for B folded slots.

    q_rows: (h_K, B·g, d); k/v_pages: (N_pages, P, h_K, d*); pages/blks:
    (h_K, nsb, block_s·steps_per_slot) from ``build_decode_steps``; pos: (B,).
    Returns (o_sel, o_win): each (h_K, B·g, dv) float32 (zeros where a branch
    saw no unmasked key — matching ``_safe_softmax`` on fully-masked rows).
    """
    h_k, rows_total, d = q_rows.shape
    page_size = k_pages.shape[1]
    dk = k_pages.shape[-1]
    dv = v_pages.shape[-1]
    nsb = pages.shape[1]
    total_steps = pages.shape[2]
    steps_per_slot = total_steps // block_s
    rows = block_s * g
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _kernel, scale=scale, g=g, block_s=block_s, page_size=page_size,
        window=window, num_sel=num_sel, steps_per_slot=steps_per_slot)
    out_spec = pl.BlockSpec((1, rows, dv), lambda hk, sb, j, pg, bl, ps: (hk, sb, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(h_k, nsb, total_steps),
        in_specs=[
            pl.BlockSpec((1, rows, d),
                         lambda hk, sb, j, pg, bl, ps: (hk, sb, 0)),
            # kv index_map composed through the page table: ``pg`` already
            # holds page_table[ids], so one grid step fetches one physical page
            pl.BlockSpec((1, page_size, 1, dk),
                         lambda hk, sb, j, pg, bl, ps: (pg[hk, sb, j], 0, hk, 0)),
            pl.BlockSpec((1, page_size, 1, dv),
                         lambda hk, sb, j, pg, bl, ps: (pg[hk, sb, j], 0, hk, 0)),
        ],
        out_specs=[out_spec, out_spec],
        scratch_shapes=[
            pltpu.VMEM((2, rows, 128), jnp.float32),
            pltpu.VMEM((2, rows, 128), jnp.float32),
            pltpu.VMEM((2, rows, dv), jnp.float32),
        ],
    )
    with jax.named_scope("paged_decode"):
        o_sel, o_win = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct((h_k, rows_total, dv), jnp.float32),
                jax.ShapeDtypeStruct((h_k, rows_total, dv), jnp.float32)],
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(pages, blks, pos, q_rows, k_pages, v_pages)
    return o_sel, o_win
