"""Version-compat shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams`` across
releases; this repo must run on both sides of the rename (the container pins
jax 0.4.37, which only has ``TPUCompilerParams``).  All kernels route through
``tpu_compiler_params`` instead of touching the class directly.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Build the TPU compiler-params object under either jax naming."""
    return _COMPILER_PARAMS_CLS(**kwargs)
