"""repro.kernels — Pallas TPU kernels for the perf-critical attention paths."""
from repro.kernels.ops import full_attention, selected_attention, sliding_attention

__all__ = ["selected_attention", "full_attention", "sliding_attention"]
