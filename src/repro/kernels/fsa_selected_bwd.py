"""Fused Pallas backward kernels for the FSA selected branch.

The forward saves ``(out, lse)`` and the backward recomputes the probability
panels from them (flash-attention backward recurrence) instead of saving the
O(N·T·B_K) score matrix:

  p  = exp(s - lse)                    (masked entries 0)
  dp = dO · Vᵀ
  ds = p ∘ (dp - delta) · scale        delta = rowsum(dO ∘ O)
  dQ = Σ ds · K        dV = Σ pᵀ · dO        dK = Σ dsᵀ · Q

Two kernels, two loop orders — both reuse the forward's index builders
(``repro.core.indexing``), nothing new is gathered:

* :func:`fsa_selected_dq` walks the **FSA forward order**: grid
  (h_K, q-blocks, union steps), scalar-prefetched per-q-block union lists
  (``build_qblock_union``).  dQ accumulates in VMEM scratch across the
  sequential union steps, exactly like the forward's online softmax.
* :func:`fsa_selected_dkv` walks the **selected-block order**: grid
  (h_K, KV blocks, occurrence steps), scalar-prefetched per-KV-block
  occurrence lists (the paper's I_i, from ``build_kvblock_qlists``).  Each
  KV block owns its dK/dV tile, so accumulation is private scratch — the
  TPU analogue of the atomics-free structure the paper's O_buf exists for.

Layouts match the forward: q/dO rows are (h_K, N·g, d) token-major
group-head-minor; lse/delta are (h_K, N·g, 128) float32 lane-broadcast
panels (``lse`` uses the fsa_faithful convention: +1e30 for maskless rows so
``exp(s - lse) == 0``).  Both kernels emit float32 grads; callers cast.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


# ---------------------------------------------------------------- dQ kernel
def _dq_kernel(kv_ids, kv_cnt, q_ref, k_ref, v_ref, sel_ref, do_ref, lse_ref,
               delta_ref, dq_ref, acc_scr, *, scale, g, block_q, block_k,
               seq_len):
    hk, iq, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    cap = pl.num_programs(2)
    rows = q_ref.shape[1]

    @pl.when(j == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j < kv_cnt[hk, iq])
    def _step():
        blk = kv_ids[hk, iq, j]
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        tok = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 0) // g
        kpos = blk * block_k + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 1)
        picked = jnp.any(sel_ref[0] == blk, axis=1, keepdims=True)
        mask = picked & (tok >= kpos) & (kpos < seq_len)
        lse = lse_ref[0][:, 0:1]
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)
        do = do_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        delta = delta_ref[0][:, 0:1]
        ds = p * (dp - delta) * scale
        acc_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == cap - 1)
    def _done():
        dq_ref[0] = acc_scr[...]


def fsa_selected_dq(q_rows, k, v, sel_rows, do_rows, lse, delta, kv_ids,
                    kv_cnt, *, g: int, block_q: int, block_k: int,
                    seq_len: int | None = None, interpret: bool = True):
    """dQ in the FSA forward loop order.  Returns (h_K, N·g, d) float32."""
    h_k, rows_total, d = q_rows.shape
    dv = v.shape[-1]
    seq_len = k.shape[1] if seq_len is None else seq_len
    nq, cap = kv_ids.shape[1], kv_ids.shape[2]
    rows = block_q * g
    t = sel_rows.shape[-1]
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_dq_kernel, scale=scale, g=g, block_q=block_q,
                               block_k=block_k, seq_len=seq_len)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(h_k, nq, cap),
        in_specs=[
            pl.BlockSpec((1, rows, d), lambda hk, iq, j, ids, cnt: (hk, iq, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda hk, iq, j, ids, cnt: (hk, ids[hk, iq, j], 0)),
            pl.BlockSpec((1, block_k, dv),
                         lambda hk, iq, j, ids, cnt: (hk, ids[hk, iq, j], 0)),
            pl.BlockSpec((1, rows, t), lambda hk, iq, j, ids, cnt: (hk, iq, 0)),
            pl.BlockSpec((1, rows, dv), lambda hk, iq, j, ids, cnt: (hk, iq, 0)),
            pl.BlockSpec((1, rows, 128), lambda hk, iq, j, ids, cnt: (hk, iq, 0)),
            pl.BlockSpec((1, rows, 128), lambda hk, iq, j, ids, cnt: (hk, iq, 0)),
        ],
        out_specs=pl.BlockSpec((1, rows, d),
                               lambda hk, iq, j, ids, cnt: (hk, iq, 0)),
        scratch_shapes=[pltpu.VMEM((rows, d), jnp.float32)],
    )
    with jax.named_scope("fsa_selected_dq"):
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((h_k, rows_total, d), jnp.float32),
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(kv_ids, kv_cnt, q_rows, k, v, sel_rows, do_rows, lse, delta)


# ------------------------------------------------------------- dK/dV kernel
def _dkv_kernel(q_ids, q_cnt, q_ref, k_ref, v_ref, sel_ref, do_ref, lse_ref,
                delta_ref, dk_ref, dv_ref, dk_scr, dv_scr, *, scale, g,
                block_q, block_k, seq_len):
    hk, ib, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    capq = pl.num_programs(2)
    rows = q_ref.shape[1]

    @pl.when(j == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    @pl.when(j < q_cnt[hk, ib])
    def _step():
        qb = q_ids[hk, ib, j]
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        tok = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 0) // g
        kpos = ib * block_k + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 1)
        picked = jnp.any(sel_ref[0] == ib, axis=1, keepdims=True)
        mask = picked & (tok >= kpos) & (kpos < seq_len)
        lse = lse_ref[0][:, 0:1]
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)
        do = do_ref[0].astype(jnp.float32)
        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        delta = delta_ref[0][:, 0:1]
        ds = p * (dp - delta) * scale
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == capq - 1)
    def _done():
        dk_ref[0] = dk_scr[...]
        dv_ref[0] = dv_scr[...]


def fsa_selected_dkv(q_rows, k, v, sel_rows, do_rows, lse, delta, q_ids,
                     q_cnt, *, g: int, block_q: int, block_k: int,
                     seq_len: int | None = None, interpret: bool = True):
    """dK/dV in the selected-block order (occurrence lists).

    Returns (dk, dv): (h_K, nb·B_K, d) / (h_K, nb·B_K, dv) float32 — padded
    to whole KV blocks; slice to seq_len and cast at the call site."""
    h_k, rows_total, d = q_rows.shape
    dv_dim = v.shape[-1]
    seq_len = k.shape[1] if seq_len is None else seq_len
    nb, capq = q_ids.shape[1], q_ids.shape[2]
    rows = block_q * g
    t = sel_rows.shape[-1]
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_dkv_kernel, scale=scale, g=g, block_q=block_q,
                               block_k=block_k, seq_len=seq_len)

    def _q_index(hk, ib, j, ids, cnt):
        return (hk, ids[hk, ib, j], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(h_k, nb, capq),
        in_specs=[
            pl.BlockSpec((1, rows, d), _q_index),
            pl.BlockSpec((1, block_k, d), lambda hk, ib, j, ids, cnt: (hk, ib, 0)),
            pl.BlockSpec((1, block_k, dv_dim),
                         lambda hk, ib, j, ids, cnt: (hk, ib, 0)),
            pl.BlockSpec((1, rows, t), _q_index),
            pl.BlockSpec((1, rows, dv_dim), _q_index),
            pl.BlockSpec((1, rows, 128), _q_index),
            pl.BlockSpec((1, rows, 128), _q_index),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), lambda hk, ib, j, ids, cnt: (hk, ib, 0)),
            pl.BlockSpec((1, block_k, dv_dim),
                         lambda hk, ib, j, ids, cnt: (hk, ib, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),
            pltpu.VMEM((block_k, dv_dim), jnp.float32),
        ],
    )
    with jax.named_scope("fsa_selected_dkv"):
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct((h_k, nb * block_k, d), jnp.float32),
                jax.ShapeDtypeStruct((h_k, nb * block_k, dv_dim),
                                     jnp.float32),
            ],
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(q_ids, q_cnt, q_rows, k, v, sel_rows, do_rows, lse, delta)
