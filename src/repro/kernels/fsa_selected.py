"""FSA selected-attention Pallas kernel — the paper's contribution, TPU-native.

The paper's FSA fills the matmul M dimension with *query tokens* attending to
the same KV block instead of padding the g (< 8) query heads of a GQA group.
On TPU the same pathology is worse (the MXU wants M = 128), and the idiomatic
gather is block-granular scalar-prefetch rather than per-token index tensors.

Organization (see DESIGN.md §2):
  grid = (h_K, num_q_blocks, union_cap)
       -- the two outer dims are core-parallel; the inner dim walks the
          scalar-prefetched *union list* of KV blocks selected by any token of
          this query block (ascending; padded by repeating the last entry so
          clamped index maps never refetch — the early-return analogue).
  M dim = B_Q · g  (all group heads folded in: one KV fetch serves the group,
          inheriting the paper's "stats once per KV head" amortization).
  Online softmax lives in VMEM scratch across the sequential inner steps — the
  TPU grid is sequential per core, so the paper's O_buf + reduction kernel
  (which exist to avoid GPU atomics) are unnecessary here.  The faithful
  three-kernel pipeline is kept in ``fsa_faithful.py`` for ablation.

Inputs (layouts produced by ops.py):
  q_rows:   (h_K, N·g, d)   token-major, group-head-minor rows
  k, v:     (h_K, N, d)
  sel_rows: (h_K, N·g, T)   per-row selected block ids, -1 where invalid
  kv_ids:   (h_K, nq, cap)  scalar-prefetch: union list per query block
  kv_cnt:   (h_K, nq)       scalar-prefetch: union length
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(kv_ids, kv_cnt, q_ref, k_ref, v_ref, sel_ref, o_ref, *rest,
            scale, g, block_q, block_k, seq_len, early_return=True,
            with_lse=False):
    if with_lse:
        lse_ref, m_scr, l_scr, acc_scr = rest
    else:
        m_scr, l_scr, acc_scr = rest
    hk, iq, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    cap = pl.num_programs(2)
    rows = q_ref.shape[1]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # early_return=False is the paper's Fig. 9 ablation: the inner loop walks
    # the full union cap, masking instead of skipping padded steps.
    @pl.when((j < kv_cnt[hk, iq]) if early_return else (j >= 0))
    def _step():
        blk = kv_ids[hk, iq, j]
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # row r is token iq*B_Q + r//g; mask = (token selected blk) & causal
        tok = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 0) // g
        kpos = blk * block_k + jax.lax.broadcasted_iota(jnp.int32, (rows, block_k), 1)
        picked = jnp.any(sel_ref[0] == blk, axis=1, keepdims=True)
        mask = picked & (tok >= kpos) & (kpos < seq_len)
        if not early_return:
            mask &= j < kv_cnt[hk, iq]
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...][:, 0:1]
        l_prev = l_scr[...][:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        l_scr[...] = jnp.broadcast_to(corr * l_prev + jnp.sum(p, 1, keepdims=True),
                                      l_scr.shape)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(j == cap - 1)
    def _done():
        l = l_scr[...][:, 0:1]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
        if with_lse:
            m = m_scr[...][:, 0:1]
            # rows with no selected keys get +inf-like lse so exp(s-lse) -> 0
            lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)),
                            -NEG_INF)
            lse_ref[0] = jnp.broadcast_to(lse, lse_ref.shape[1:])


def fsa_selected(q_rows, k, v, sel_rows, kv_ids, kv_cnt, *, g: int,
                 block_q: int, block_k: int, seq_len: int | None = None,
                 interpret: bool = True, early_return: bool = True,
                 return_lse: bool = False):
    """Returns (h_K, N·g, d) selected-attention output (zeros for maskless rows).

    With ``return_lse=True`` also returns the per-row log-sum-exp in the
    flash-backward residual layout (h_K, N·g, 128) float32 (lane-broadcast;
    same convention as ``fsa_faithful``'s statistics kernel) for the fused
    backward pass."""
    h_k, rows_total, d = q_rows.shape
    dv = v.shape[-1]
    # seq_len is the logical key count: k/v may carry padding rows up to a
    # whole number of KV blocks (keys at positions >= seq_len are masked)
    seq_len = k.shape[1] if seq_len is None else seq_len
    nq = kv_ids.shape[1]
    cap = kv_ids.shape[2]
    rows = block_q * g
    t = sel_rows.shape[-1]
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_kernel, scale=scale, g=g, block_q=block_q,
                               block_k=block_k, seq_len=seq_len,
                               early_return=early_return, with_lse=return_lse)
    out_specs = [pl.BlockSpec((1, rows, dv),
                              lambda hk, iq, j, ids, cnt: (hk, iq, 0))]
    out_shape = [jax.ShapeDtypeStruct((h_k, rows_total, dv), q_rows.dtype)]
    if return_lse:
        out_specs.append(pl.BlockSpec((1, rows, 128),
                                      lambda hk, iq, j, ids, cnt: (hk, iq, 0)))
        out_shape.append(
            jax.ShapeDtypeStruct((h_k, rows_total, 128), jnp.float32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(h_k, nq, cap),
        in_specs=[
            pl.BlockSpec((1, rows, d), lambda hk, iq, j, ids, cnt: (hk, iq, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda hk, iq, j, ids, cnt: (hk, ids[hk, iq, j], 0)),
            pl.BlockSpec((1, block_k, dv),
                         lambda hk, iq, j, ids, cnt: (hk, ids[hk, iq, j], 0)),
            pl.BlockSpec((1, rows, t), lambda hk, iq, j, ids, cnt: (hk, iq, 0)),
        ],
        out_specs=out_specs if return_lse else out_specs[0],
        scratch_shapes=[
            pltpu.VMEM((rows, 128), jnp.float32),
            pltpu.VMEM((rows, 128), jnp.float32),
            pltpu.VMEM((rows, dv), jnp.float32),
        ],
    )
    with jax.named_scope("fsa_selected"):
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=out_shape if return_lse else out_shape[0],
            compiler_params=tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(kv_ids, kv_cnt, q_rows, k, v, sel_rows)
