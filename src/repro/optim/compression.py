"""Error-feedback gradient compression for the cross-pod all-reduce.

At 1000+ nodes the pod-to-pod hop is DCN, not ICI; int8 quantization with an
error-feedback residual cuts that traffic 4x (bf16→int8 + scales) with no
asymptotic accuracy loss (the residual re-injects quantization error next
step).  Applied only to the DP gradient reduction — TP collectives stay
full-precision.

Off by default; enable via TrainLoopConfig.grad_compression.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g, residual):
    """-> (int8 payload, scale, new_residual). Shapes preserved."""
    g32 = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(g32)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g32 - deq


def decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residuals):
    out = jax.tree.map(compress, grads, residuals)
    is3 = lambda t: isinstance(t, tuple) and len(t) == 3
    qs = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    scales = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    res = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    return qs, scales, res


def decompress_tree(qs, scales):
    return jax.tree.map(decompress, qs, scales)
