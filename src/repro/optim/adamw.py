"""Sharded AdamW with fp32 master weights, built for FSDP.

Optimizer state inherits each parameter's PartitionSpec (ZeRO-3: moments and
master copies are sharded exactly like the parameter), so memory per device
is 12 bytes/param ÷ (data × model shards).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_fp32: bool = True


def init_opt_state(params, cfg: AdamWConfig):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
    }
    if cfg.master_fp32:
        # jnp.array(copy=True): a bf16->f32 astype of f32 params would alias
        # the param buffer and break donation
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return state


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state, cfg: AdamWConfig,
                  lr_scale: jnp.ndarray | float = 1.0,
                  skip: jnp.ndarray | bool = False):
    """One AdamW step.  ``skip`` (traced bool) freezes the update — used by
    the fault-tolerance runtime to drop steps with non-finite gradients."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    finite = jnp.isfinite(gnorm)
    skip = jnp.logical_or(skip, ~finite)
    clip = jnp.where(cfg.grad_clip > 0,
                     jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)), 1.0)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    masters = state.get("master", params)

    def upd(p, g, m, v, mast):
        g = g.astype(jnp.float32) * clip
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        mast32 = mast.astype(jnp.float32)
        delta = lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * mast32)
        new_master = mast32 - delta
        keep = skip
        m_new = jnp.where(keep, m, m_new)
        v_new = jnp.where(keep, v, v_new)
        new_master = jnp.where(keep, mast32, new_master)
        return new_master.astype(p.dtype), m_new, v_new, new_master

    out = jax.tree.map(upd, params, grads, state["m"], state["v"], masters)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"step": jnp.where(skip, state["step"], step),
                 "m": new_m, "v": new_v}
    if cfg.master_fp32:
        new_state["master"] = jax.tree.map(lambda t: t[3], out,
                                           is_leaf=lambda t: isinstance(t, tuple))
    return new_params, new_state, {"grad_norm": gnorm, "skipped": skip}


def opt_state_specs(param_specs_tree, cfg: AdamWConfig):
    """Optimizer-state PartitionSpecs mirroring the parameter specs."""
    from jax.sharding import PartitionSpec as P

    state = {"step": P(), "m": param_specs_tree, "v": param_specs_tree}
    if cfg.master_fp32:
        state["master"] = param_specs_tree
    return state
