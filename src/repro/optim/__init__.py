"""repro.optim — sharded AdamW, schedules, gradient compression."""
from repro.optim.adamw import (AdamWConfig, apply_updates, global_norm,
                               init_opt_state, opt_state_specs)
from repro.optim.schedule import constant, cosine_with_warmup
