"""Pipeline parallelism: circular 1F1B-style schedule over a "pipe" mesh axis.

Implemented with shard_map + ppermute (the JAX-native pattern): each pipe
group owns one contiguous stage of layers; microbatch activations rotate
through stages; the bubble is (n_stages - 1) of (n_micro + n_stages - 1)
ticks.  Used as an optional alternative to FSDP for the 104B config —
cross-stage traffic is one (B_micro, S, D) activation per tick instead of
per-layer weight all-gathers, which is the right trade at very large D.

``pipeline_forward`` is schedule-correct for the forward pass; training uses
jax.grad through it (scan-of-ppermute transposes to the reverse schedule
automatically — the 1F1B memory profile then comes from remat on stage_fn).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.axes import axis_size, pvary


def pipeline_forward(stage_fn, stage_params, x, *, axis: str = "pipe"):
    """Run inside shard_map over ``axis``.

    stage_fn: (params_for_stage, activations) -> activations
    stage_params: params with leading stage dim SHARDED over ``axis`` (each
        group sees its own slice with leading dim 1).
    x: (n_micro, B_micro, S, D) microbatched input, replicated over ``axis``.
    Returns (n_micro, B_micro, S, D) final-stage outputs (valid on the last
    stage; callers psum-select or gather as needed).
    """
    n_stages = axis_size(axis)
    stage = jax.lax.axis_index(axis)
    n_micro = x.shape[0]
    ticks = n_micro + n_stages - 1

    my_params = jax.tree.map(lambda a: a[0], stage_params)
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def tick(carry, t):
        inflight, outputs = carry
        # stage 0 injects microbatch t (if any); others take the rotated act
        mb_idx = jnp.clip(t, 0, n_micro - 1)
        inject = x[mb_idx]
        cur = jnp.where(stage == 0, inject, inflight)
        out = stage_fn(my_params, cur)
        # last stage records its finished microbatch (t - n_stages + 1)
        done_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
        is_done = (stage == n_stages - 1) & (t >= n_stages - 1)
        outputs = jax.lax.dynamic_update_index_in_dim(
            outputs,
            jnp.where(is_done, out, outputs[done_idx]),
            done_idx, 0)
        nxt = jax.lax.ppermute(out, axis, perm)
        return (nxt, outputs), None

    init = pvary((jnp.zeros_like(x[0]), jnp.zeros_like(x)), (axis,))
    (_, outputs), _ = jax.lax.scan(tick, init, jnp.arange(ticks))
    # broadcast final outputs from the last stage to all groups
    outputs = jax.lax.ppermute(
        outputs, axis, [( (n_stages - 1 + i) % n_stages, i) for i in range(n_stages)])
    # after rotation by 1 from last stage, stage 0 holds them; share via psum
    mask = (stage == 0).astype(outputs.dtype)
    return jax.lax.psum(outputs * mask, axis)


def make_pipelined_backbone(block_fn, n_layers: int, n_stages: int,
                            mesh, *, axis: str = "pipe"):
    """Wrap a per-layer block into a pipelined backbone.

    block_fn: (layer_params, x) -> x.  Layers are grouped into n_stages
    contiguous stages of n_layers // n_stages layers (stacked params).
    Returns fn(stacked_params, x_microbatched) for use under jit with
    ``mesh`` containing the ``axis`` dimension.
    """
    assert n_layers % n_stages == 0
    per = n_layers // n_stages

    def stage_fn(params_stage, x):
        def body(h, p_layer):
            return block_fn(p_layer, h), None
        # params_stage: (per, ...) slice of this stage's layers
        h, _ = jax.lax.scan(body, x, params_stage)
        return h

    def fn(stacked_params, x_micro):
        # stacked_params leading dim = n_layers -> (n_stages, per, ...)
        grouped = jax.tree.map(
            lambda a: a.reshape((n_stages, per) + a.shape[1:]), stacked_params)
        from jax.experimental.shard_map import shard_map

        pipe = shard_map(
            functools.partial(pipeline_forward, stage_fn, axis=axis),
            mesh=mesh,
            in_specs=(P(axis), P()),
            out_specs=P(),
        )
        return pipe(grouped, x_micro)

    return fn
