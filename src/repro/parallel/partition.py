"""Parameter partitioning: name-based rules -> PartitionSpec trees.

Scheme (Megatron TP × ZeRO-3 FSDP):
  * TP ("model" axis): attention heads / MLP hidden / experts / vocab;
  * FSDP ("data" axis): the non-TP major dim of every large matrix;
  * "pod" axis: pure data parallelism — parameters replicated across pods,
    gradients all-reduced (the only cross-pod collective), which is the right
    hierarchy for DCN-connected pods at 1000+ nodes.

Stacked leading dims (scan-over-layers / hybrid groups) are auto-padded with
None.  Base specs are defined over the *trailing* dims of each leaf.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# name -> (base_rank, trailing spec)
_BY_NAME: dict[str, tuple[int, tuple]] = {
    # embeddings / heads
    "embed": (2, ("model", "data")),
    "lm_head": (2, ("data", "model")),
    "pos_enc": (2, (None, "data")),
    "img_proj": (2, ("data", None)),
    # attention projections
    "w_q": (2, ("data", "model")),
    "w_k": (2, ("data", "model")),
    "w_v": (2, ("data", "model")),
    "w_o": (2, ("model", "data")),
    "b_q": (1, ("model",)),
    "b_k": (1, ("model",)),
    "b_v": (1, ("model",)),
    # MLA
    "w_dkv": (2, ("data", None)),
    "w_kr": (2, ("data", None)),
    "w_uk": (3, ("model", None, None)),
    "w_uv": (3, ("model", None, None)),
    # dense MLP
    "w_in": (2, ("data", "model")),
    "w_gate": (2, ("data", "model")),
    "w_out": (2, ("model", "data")),
    # mamba mixer
    "conv_w": (2, (None, "model")),
    "conv_b": (1, ("model",)),
    # router
    "router": (2, ("data", None)),
}

_REPLICATED = {
    "ln1", "ln2", "ln3", "ln", "final_norm", "norm", "kv_norm", "enc_ln",
    "dec_ln", "scale", "bias", "A_log", "D", "dt_bias", "pe_k", "pe_v",
}

# (parent, name) overrides
_BY_PARENT: dict[tuple[str, str], tuple[int, tuple]] = {
    ("nsa", "w_k"): (2, (None, None)),
    ("nsa", "w_v"): (2, (None, None)),
    ("nsa", "w_gate"): (3, ("data", "model", None)),
    ("moe", "w_gate"): (3, ("model", "data", None)),
    ("moe", "w_in"): (3, ("model", "data", None)),
    ("moe", "w_out"): (3, ("model", None, "data")),
    ("mixer", "w_in"): (2, ("data", "model")),
    ("mixer", "w_out"): (2, ("model", "data")),
}


def _leaf_spec(path: tuple[str, ...], x) -> P:
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    if name in _REPLICATED:
        return P()
    rule = _BY_PARENT.get((parent, name)) or _BY_NAME.get(name)
    if rule is None:
        return P()  # unknown small param: replicate
    base_rank, spec = rule
    pad = x.ndim - base_rank
    assert pad >= 0, f"param {'/'.join(path)} rank {x.ndim} < base {base_rank}"
    return P(*((None,) * pad + tuple(spec)))


def _path_str(kp) -> tuple[str, ...]:
    out = []
    for k in kp:
        out.append(getattr(k, "key", getattr(k, "idx", None)))
    return tuple(str(k) for k in out)


def _filter_spec(spec: P, shape, mesh) -> P:
    """Drop mesh axes that are absent or do not divide the dim evenly."""
    if mesh is None:
        return spec
    sizes = dict(mesh.shape)
    out = []
    for i, a in enumerate(spec):
        if a is None:
            out.append(None)
            continue
        axes = (a,) if isinstance(a, str) else tuple(a)
        total = 1
        kept = []
        for ax in axes:
            if ax in sizes and shape[i] % (total * sizes[ax]) == 0:
                kept.append(ax)
                total *= sizes[ax]
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def param_specs(params, mesh=None):
    """PartitionSpec tree for a param tree; axes absent from ``mesh`` or not
    dividing the dim are dropped (the same rules serve 1-device tests and
    512-device pods)."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: _filter_spec(_leaf_spec(_path_str(kp), x), x.shape, mesh),
        params)


def param_shardings(params, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh))


def batch_specs(batch, mesh):
    """Shard every batch input over (pod, data) on the leading (batch) dim;
    if the batch doesn't divide (e.g. long_500k B=1), shard the sequence."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= dict(mesh.shape)[a]
    dp_axis = dp if len(dp) > 1 else (dp[0] if dp else None)

    def spec(x):
        if getattr(x, "ndim", 0) == 0:
            return P()
        s = [None] * x.ndim
        if x.shape[0] % dp_size == 0:
            s[0] = dp_axis
        elif x.ndim > 1 and x.shape[1] % dp_size == 0:
            s[1] = dp_axis          # sequence (context) parallelism fallback
        return _filter_spec(P(*s), x.shape, mesh)

    return jax.tree.map(spec, batch)


# serving layout: pure tensor parallelism over "model" for the ATTENTION
# projections only (head-sharded to match the KV-head-sharded page pools of
# repro.serving.sharded), everything else replicated.  Unlike the training
# tables above there is NO FSDP: every replica of the "data" axis runs an
# independent engine over the full (replicated) non-attention weights, so a
# decode tick needs exactly one collective — the psum completing w_o's
# partial sum.
_SERVE_BY_NAME: dict[str, tuple[int, tuple]] = {
    "w_q": (2, (None, "model")),
    "w_k": (2, (None, "model")),
    "w_v": (2, (None, "model")),
    "w_o": (2, ("model", None)),
    "b_q": (1, ("model",)),
    "b_k": (1, ("model",)),
    "b_v": (1, ("model",)),
}

_SERVE_BY_PARENT: dict[tuple[str, str], tuple[int, tuple]] = {
    # NSA compression MLPs are headless (dk, dk) — replicated; the gating
    # projection (d, h, 3) is per-head — sharded with the heads
    ("nsa", "w_k"): (2, (None, None)),
    ("nsa", "w_v"): (2, (None, None)),
    ("nsa", "w_gate"): (3, (None, "model", None)),
}


def _serve_leaf_spec(path: tuple[str, ...], x) -> P:
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    rule = _SERVE_BY_PARENT.get((parent, name)) or _SERVE_BY_NAME.get(name)
    if rule is None:
        return P()          # embed/lm_head/norms/MLP/MoE: replicated
    base_rank, spec = rule
    pad = x.ndim - base_rank
    assert pad >= 0, f"param {'/'.join(path)} rank {x.ndim} < base {base_rank}"
    return P(*((None,) * pad + tuple(spec)))


def serve_param_specs(params, mesh=None):
    """PartitionSpec tree for the SERVING layout (see table above): attention
    projections head-sharded over "model", all else replicated across the
    whole mesh."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: _filter_spec(_serve_leaf_spec(_path_str(kp), x),
                                   x.shape, mesh),
        params)


def cache_specs_tree(cache, mesh):
    """Decode caches, identified by leaf name:
      k/v/cmp_k/cmp_v/cross_k/cross_v: (..., B, S, h_K, d) — batch on dp,
        KV heads on model;
      conv: (..., B, K-1, C) — batch on dp, channels on model;
      ssm:  (..., B, H, P, N) — batch on dp, heads on model.
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_axis = dp if len(dp) > 1 else (dp[0] if dp else None)
    has_model = "model" in mesh.axis_names

    def spec(kp, x):
        name = _path_str(kp)[-1]
        s = [None] * x.ndim
        if name in ("k", "v", "cmp_k", "cmp_v", "cross_k", "cross_v"):
            dp_size = 1
            for a in dp:
                dp_size *= dict(mesh.shape)[a]
            if x.shape[-4] % dp_size == 0:
                s[-4] = dp_axis
            else:
                s[-3] = dp_axis     # long-context: shard the sequence instead
            if has_model:
                model_size = dict(mesh.shape)["model"]
                if x.shape[-2] % model_size == 0:
                    s[-2] = "model"
                else:
                    # few KV heads: context-parallel cache (seq over model)
                    prev = s[-3]
                    prev_t = (() if prev is None else
                              ((prev,) if isinstance(prev, str) else tuple(prev)))
                    s[-3] = prev_t + ("model",)
        elif name == "conv":
            s[-3] = dp_axis
            if has_model:
                s[-1] = "model"
        elif name == "ssm":
            s[-4] = dp_axis
            if has_model:
                s[-3] = "model"
        elif x.ndim:
            s[0] = dp_axis
        return _filter_spec(P(*s), x.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, cache)
