"""Logical-axis activation sharding.

Model code annotates activations with *logical* axis names via ``shard``;
the mapping to physical mesh axes lives here, so models stay mesh-agnostic.
Outside a mesh context (unit tests, single CPU) annotations are no-ops.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

# logical activation axis -> mesh axis (or tuple, or None)
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,            # attention-internal tensors stay head-sharded
    "seq_sp": "model",      # residual stream: sequence parallelism (saved
                            # activations shard over "model"; XLA inserts the
                            # Megatron-SP all-gather/reduce-scatter pairs)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert": "model",
    "vocab": "model",
    "state": None,
    "cap": None,
}

_local = threading.local()


def axis_size(axis: str) -> int:
    """Size of a named mesh axis inside shard_map (jax.lax.axis_size is
    missing on 0.4.x; psum of 1 is the portable spelling)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis)
    return jax.lax.psum(1, axis)


def pvary(x, axes):
    """jax.lax.pvary where it exists (newer shard_map varying-type checks);
    identity on 0.4.x, which has no varying types."""
    fn = getattr(jax.lax, "pvary", None)
    if fn is not None:
        return fn(x, axes)
    return x


def current_mesh():
    """The ambient mesh (abstract on jax >= 0.5, physical on 0.4.x).

    Both objects expose ``.empty``, ``.shape`` and ``.axis_names``, which is
    all ``resolve``/``shard`` need.
    """
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        return get_abstract()
    from jax._src.mesh import thread_resources
    return thread_resources.env.physical_mesh


def current_rules() -> dict[str, object]:
    return getattr(_local, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def axis_rules(rules: dict[str, object]):
    """Override logical→mesh rules (e.g. enable sequence parallelism)."""
    prev = current_rules()
    _local.rules = {**prev, **rules}
    try:
        yield
    finally:
        _local.rules = prev


def resolve(*names: str | None, shape: tuple[int, ...] | None = None) -> P:
    """Map logical names to mesh axes; axes that do not divide the
    corresponding dim (e.g. 8 KV heads over a 16-way model axis) are dropped."""
    rules = current_rules()
    mesh = current_mesh()
    sizes = dict(mesh.shape) if not mesh.empty else {}
    if shape is not None:  # tolerate rank mismatch (e.g. decode drops seq dim)
        names = tuple(names)[:len(shape)] + (None,) * max(0, len(shape) - len(names))
    axes = []
    used: set[str] = set()
    for i, n in enumerate(names):
        r = rules.get(n) if n is not None else None
        if r is None:
            axes.append(None)
            continue
        rt = (r,) if isinstance(r, str) else tuple(r)
        rt = tuple(a for a in rt if a in mesh.axis_names and a not in used)
        if shape is not None and rt:
            total = 1
            kept = []
            for a in rt:
                if shape[i] % (total * sizes.get(a, 1)) == 0:
                    kept.append(a)
                    total *= sizes.get(a, 1)
            rt = tuple(kept)
        used.update(rt)
        axes.append(rt if len(rt) > 1 else (rt[0] if rt else None))
    return P(*axes)


def shard(x, *names: str | None):
    """Constrain activation ``x`` to the resolved logical sharding (no-op
    outside a mesh context)."""
    mesh = current_mesh()
    if mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, resolve(*names, shape=tuple(x.shape)))
