"""Overlapped all-gather matmul (collective matmul), shard_map + ppermute.

The Megatron TP forward needs y = x @ W with x sequence-sharded (SP) and W
column-sharded: the naive lowering all-gathers x *then* multiplies, leaving
the ICI idle during compute and the MXU idle during the gather.  The
collective matmul rotates x shards around the ring, multiplying each arriving
shard against the local W — compute hides (n-1)/n of the communication.

XLA's latency-hiding scheduler can do this rewrite itself on TPU
(`--xla_tpu_enable_async_collective_fusion` etc., see launch/xla_flags.py);
this explicit version is for when the automatic pass declines, and as the
unit-testable reference of the trick (tests/test_multidevice.py runs it on 8
forced host devices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.axes import axis_size, pvary


def _ag_matmul_body(x_shard, w_local, *, axis: str):
    """x_shard: (S/n, D) local sequence shard; w_local: (D, F/n) local cols.
    Returns (S, F/n): the full-sequence activation for the local columns."""
    n = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    s_shard = x_shard.shape[0]
    perm = [(i, (i + 1) % n) for i in range(n)]

    out = jnp.zeros((s_shard * n, w_local.shape[1]), x_shard.dtype)
    # mark the accumulator as device-varying for the shard_map scan typing
    out = pvary(out, (axis,))

    def step(carry, i):
        x_cur, out = carry
        # the shard we currently hold originated at ring position (idx - i)
        src = (idx - i) % n
        y = x_cur @ w_local                      # compute overlaps the send
        x_nxt = jax.lax.ppermute(x_cur, axis, perm)
        out = jax.lax.dynamic_update_slice_in_dim(out, y, src * s_shard, 0)
        return (x_nxt, out), None

    (x_cur, out), _ = jax.lax.scan(step, (x_shard, out), jnp.arange(n))
    return out


def all_gather_matmul(x, w, mesh, *, axis: str = "model"):
    """x: (S, D) sharded P(axis, None); w: (D, F) sharded P(None, axis).
    Returns (S, F) sharded P(None, axis) — same math as (all_gather(x) @ w)."""
    from jax.experimental.shard_map import shard_map

    fn = shard_map(
        functools.partial(_ag_matmul_body, axis=axis),
        mesh=mesh,
        in_specs=(P(axis, None), P(None, axis)),
        out_specs=P(None, axis),
    )
    return fn(x, w)
