"""repro.parallel — logical-axis sharding, partitioning rules, pipeline."""
