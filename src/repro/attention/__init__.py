"""repro.attention — unified attention dispatch API.

One functional entry (:func:`nsa_attention`), a capability-based backend
registry (:func:`register_backend` / :func:`resolve` /
:func:`list_backends`), and the :class:`KernelPolicy` implementation bundle
split out of :class:`~repro.core.nsa_config.NSAConfig`.

All string/bool implementation dispatch lives inside this package; pick
backends with ``KernelPolicy`` (or a ``backend=`` registry name at the call
site), never with config booleans.
"""
from repro.core.nsa_config import KernelPolicy, NSAConfig

from repro.attention.registry import (
    ALGORITHMS,
    MODES,
    AttentionBackend,
    AttentionRequest,
    BackendResolutionError,
    Capabilities,
    capable_backends,
    explain,
    get_backend,
    list_backends,
    near_misses,
    register_backend,
    resolve,
    unsupported_reason,
    unsupported_reasons,
)
from repro.attention import backends as _backends  # registers the backends
from repro.attention.api import normalize_backend_name, nsa_attention
from repro.attention.backends import (
    SELECTED_KERNELS,
    default_selected_kernel,
    flash_attention,
    paged_decode_attention,
    selected_attention,
    sparse_selected_fn,
)
from repro.attention.vjp import kernel_vjp, twin_vjp

__all__ = [
    "ALGORITHMS",
    "MODES",
    "AttentionBackend",
    "AttentionRequest",
    "BackendResolutionError",
    "Capabilities",
    "KernelPolicy",
    "NSAConfig",
    "SELECTED_KERNELS",
    "capable_backends",
    "default_selected_kernel",
    "explain",
    "flash_attention",
    "get_backend",
    "kernel_vjp",
    "list_backends",
    "near_misses",
    "normalize_backend_name",
    "nsa_attention",
    "paged_decode_attention",
    "register_backend",
    "resolve",
    "selected_attention",
    "sparse_selected_fn",
    "twin_vjp",
    "unsupported_reason",
    "unsupported_reasons",
]
