"""Registered attention backends.

Each backend wraps one kernel organization of the same math and registers
itself with declared capabilities.  All string/bool implementation dispatch
in the repo lives in this package; outside it, callers go through
``repro.attention.nsa_attention`` / ``resolve``.

Backends (see README "Attention API" for the full table):

  fsa            FSA-TPU Pallas kernel for the selected branch (block-union)
  fsa_faithful   paper-structure three-kernel pipeline (ablation)
  nsa            vanilla-NSA-style baseline kernel (g padded to 8)
  sparse_union   FSA organization in XLA ops (production CPU/backward path)
  sparse_gather  naive per-token gather (baseline; also the decode backend)
  reference      dense-mask oracle for every algorithm and mode
  flash_full     Pallas flash full attention
  flash_sliding  Pallas flash sliding-window attention
  paged_kernel   Pallas paged-decode kernel (serving; slots folded into M)
  paged_gather   gather-through-page-table paged decode (serving reference)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import indexing, sparse
from repro.core import attention as core_attn
from repro.core.nsa_config import NSAConfig
from repro.core.paging import gather_rows
from repro.core.reference import (_gqa_out, _gqa_scores, _safe_softmax,
                                  nsa_attention_ref)
from repro.kernels import flash_attention as _flash
from repro.kernels import fsa_faithful as _faithful
from repro.kernels import fsa_selected as _fsa
from repro.kernels import fsa_selected_bwd as _fsa_bwd
from repro.kernels import nsa_selected as _nsa
from repro.kernels import paged_decode as _paged
from repro.kernels import ref as _ref
from repro.attention.registry import Capabilities, register_backend
from repro.attention.vjp import kernel_vjp

SELECTED_KERNELS = ("fsa", "fsa_faithful", "nsa", "reference")
# selected-branch kernels with a fused Pallas backward (others fall back to
# the XLA twin under the same kernel_vjp op)
FUSED_BWD_SELECTED = ("fsa", "fsa_faithful")


def _pad_tokens(x, n_pad):
    return jnp.pad(x, ((0, n_pad - x.shape[0]),) + ((0, 0),) * (x.ndim - 1))


def _q_padding(cfg, n):
    """(block_q, padded token count) for an N-token query sequence."""
    bq = min(cfg.q_block_size, max(8, n))
    return bq, ((n + bq - 1) // bq) * bq


def _kv_layout(k, v, block_k):
    """(S, h_K, d) k/v -> kernel layout (h_K, S_pad, d) padded to whole KV
    blocks (a partial trailing block would read out of bounds); returns the
    logical S for the kernels' key-position masks."""
    s = k.shape[0]
    s_pad = ((s + block_k - 1) // block_k) * block_k
    return (_pad_tokens(k, s_pad).transpose(1, 0, 2),
            _pad_tokens(v, s_pad).transpose(1, 0, 2), s)


def _delta_panels(do_rows, o_rows):
    """delta = rowsum(dO ∘ O) broadcast to the (h_K, N·g, 128) residual
    panel layout the backward kernels read (lane-broadcast like lse)."""
    delta = jnp.sum(do_rows.astype(jnp.float32) * o_rows.astype(jnp.float32),
                    axis=-1, keepdims=True)
    return jnp.broadcast_to(delta, delta.shape[:-1] + (128,))


# =====================================================================
# selected branch: Pallas kernel forward, fused Pallas backward for the
# FSA kernels, chunked-gather XLA twin as the fallback backward
# =====================================================================
def _normalize_selection(idxp, validp):
    """Ascending sort, duplicates invalidated (top-k selection never produces
    dups, but the kernel contract must not depend on that)."""
    key = jnp.where(validp, idxp, jnp.iinfo(jnp.int32).max // 2)
    order = jnp.argsort(key, axis=-1)
    idxp = jnp.take_along_axis(idxp, order, axis=-1)
    validp = jnp.take_along_axis(validp, order, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros_like(validp[..., :1]),
         (idxp[..., 1:] == idxp[..., :-1]) & validp[..., 1:] & validp[..., :-1]],
        axis=-1)
    validp &= ~dup
    return idxp, validp


def _selected_run(static, q, k, v, idx, valid, want_lse):
    cfg, kernel = static
    n, h, d = q.shape
    h_k = k.shape[1]
    g = h // h_k
    bq, n_pad = _q_padding(cfg, n)

    qp = _pad_tokens(q, n_pad)
    idxp, validp = _normalize_selection(_pad_tokens(idx, n_pad),
                                        _pad_tokens(valid, n_pad))
    sel = jnp.where(validp, idxp, -1).astype(jnp.int32)       # (N, h_K, T)
    # rows layout for sel: repeat each token's list over the g group heads
    sel_rows = jnp.repeat(sel.transpose(1, 0, 2), g, axis=1)  # (h_K, N·g, T)
    q_rows = _ref.rows_from_heads(qp, h_k)
    k_t, v_t, s = _kv_layout(k, v, cfg.block_size)

    if kernel == "nsa":
        g_pad = max(g, 8)
        q_pad = qp.reshape(n_pad, h_k, g, d).transpose(1, 0, 2, 3)
        q_pad = jnp.pad(q_pad, ((0, 0), (0, 0), (0, g_pad - g), (0, 0)))
        o = _nsa.nsa_selected(q_pad, k_t, v_t, sel.transpose(1, 0, 2),
                              block_k=cfg.block_size, seq_len=s,
                              interpret=cfg.interpret)
        o = o[:, :, :g].transpose(1, 0, 2, 3).reshape(n_pad, h, -1)
        return o[:n], None

    kv_ids, kv_cnt = indexing.build_qblock_union(idxp, validp, cfg, s)
    if kernel == "fsa":
        o_rows = _fsa.fsa_selected(q_rows, k_t, v_t, sel_rows, kv_ids, kv_cnt,
                                   g=g, block_q=bq, block_k=cfg.block_size,
                                   seq_len=s, interpret=cfg.interpret,
                                   return_lse=want_lse)
    elif kernel == "fsa_faithful":
        q_ids, slot_ids, q_cnt = indexing.build_kvblock_qlists(
            idxp, validp, cfg, s, union_cap=kv_ids.shape[-1])
        o_rows = _faithful.fsa_faithful(q_rows, k_t, v_t, sel_rows, kv_ids,
                                        kv_cnt, q_ids, slot_ids, q_cnt, g=g,
                                        block_q=bq, block_k=cfg.block_size,
                                        seq_len=s, interpret=cfg.interpret,
                                        return_lse=want_lse)
    elif kernel == "reference":
        return _ref.selected_ref(q, k, v, idx, valid, cfg), None
    else:
        raise ValueError(f"unknown selected kernel: {kernel}")
    if want_lse:
        o_rows, lse = o_rows
        return _ref.heads_from_rows(o_rows, n_pad)[:n], (o_rows, lse, sel)
    return _ref.heads_from_rows(o_rows, n_pad)[:n], None


def _selected_fwd_impl(static, q, k, v, idx, valid):
    return _selected_run(static, q, k, v, idx, valid, want_lse=False)[0]


def _selected_fused_fwd(static, q, k, v, idx, valid):
    """Forward for the VJP: FSA kernels emit (out, lse) residuals; kernels
    without a fused backward return residuals=None (twin fallback)."""
    _, kernel = static
    want = kernel in FUSED_BWD_SELECTED
    return _selected_run(static, q, k, v, idx, valid, want_lse=want)


def _selected_fused_bwd(static, res, tensors, dout):
    """Fused dQ/dK/dV: rebuilds the forward's index lists (union lists for
    dQ, occurrence lists for dK/dV) from the saved normalized selection and
    launches the Pallas backward kernels."""
    cfg, _ = static
    o_rows, lse, sel = res
    q, k, v = tensors[:3]
    n, h, d = q.shape
    s, h_k, _ = k.shape
    g = h // h_k
    bq, n_pad = _q_padding(cfg, n)

    idxp, validp = jnp.maximum(sel, 0), sel >= 0
    sel_rows = jnp.repeat(sel.transpose(1, 0, 2), g, axis=1)
    q_rows = _ref.rows_from_heads(_pad_tokens(q, n_pad), h_k)
    k_t, v_t, s = _kv_layout(k, v, cfg.block_size)
    do_rows = _ref.rows_from_heads(_pad_tokens(dout, n_pad), h_k)
    delta = _delta_panels(do_rows, o_rows)

    kv_ids, kv_cnt = indexing.build_qblock_union(idxp, validp, cfg, s)
    q_ids, _, q_cnt = indexing.build_kvblock_qlists(idxp, validp, cfg, s)
    kw = dict(g=g, block_q=bq, block_k=cfg.block_size, seq_len=s,
              interpret=cfg.interpret)
    dq_rows = _fsa_bwd.fsa_selected_dq(q_rows, k_t, v_t, sel_rows, do_rows,
                                       lse, delta, kv_ids, kv_cnt, **kw)
    dk_t, dv_t = _fsa_bwd.fsa_selected_dkv(q_rows, k_t, v_t, sel_rows,
                                           do_rows, lse, delta, q_ids, q_cnt,
                                           **kw)
    dq = _ref.heads_from_rows(dq_rows, n_pad)[:n].astype(q.dtype)
    dk = dk_t[:, :s].transpose(1, 0, 2).astype(k.dtype)
    dv = dv_t[:, :s].transpose(1, 0, 2).astype(v.dtype)
    return dq, dk, dv


def _selected_twin(static, q, k, v, idx, valid):
    """Differentiable twin of the selected kernels (chunked gather path)."""
    cfg, _ = static
    return sparse.selected_gather_chunked(q, k, v, idx, valid, cfg)


_selected_op = kernel_vjp(_selected_fwd_impl, _selected_twin, num_diff=3,
                          fused_fwd=_selected_fused_fwd,
                          fused_bwd=_selected_fused_bwd)


def default_selected_kernel(cfg: NSAConfig) -> str:
    """The Pallas selected-branch kernel the policy names (fsa if the policy
    names a non-kernel backend such as ``auto`` or ``sparse_union``)."""
    b = cfg.policy.backend
    return b if b in SELECTED_KERNELS else "fsa"


def selected_attention(q, k, v, idx, valid, cfg: NSAConfig,
                       kernel: str | None = None):
    """Selected-branch attention through the named Pallas kernel.
    q: (N,h,d), k/v: (S,h_K,d), idx/valid: (N,h_K,T)."""
    return _selected_op((cfg, kernel or default_selected_kernel(cfg)),
                        q, k, v, idx, valid)


# =====================================================================
# flash full / sliding: Pallas kernel forward, fused Pallas backward,
# chunked-reference twin kept as the VJP scaffolding fallback
# =====================================================================
def _flash_layouts(cfg, q, k, v):
    """Kernel layouts for flash.  Q pads to whole q blocks, K/V to whole kv
    blocks (a partial trailing block would read out of bounds); the padding
    amounts differ, so the *logical* causal alignment (key position of query
    token 0) and key count are passed explicitly — the kernel's default
    end-of-array alignment would shift the causal band for ragged N."""
    n, h, d = q.shape
    s, h_k, _ = k.shape
    g = h // h_k
    bq, n_pad = _q_padding(cfg, n)
    bk = min(128, s)
    q_rows = _ref.rows_from_heads(_pad_tokens(q, n_pad), h_k)
    k_t, v_t, _ = _kv_layout(k, v, bk)
    return q_rows, k_t, v_t, dict(g=g, block_q=bq, block_k=bk, valid_k=s,
                                  offset=s - n, interpret=cfg.interpret), n_pad


def _flash_run(static, q, k, v, want_lse):
    cfg, causal, window = static
    n = q.shape[0]
    q_rows, k_t, v_t, kw, n_pad = _flash_layouts(cfg, q, k, v)
    res = _flash.flash_attention(q_rows, k_t, v_t, causal=causal,
                                 window=window, return_lse=want_lse, **kw)
    if want_lse:
        o_rows, lse = res
        return _ref.heads_from_rows(o_rows, n_pad)[:n], (o_rows, lse)
    return _ref.heads_from_rows(res, n_pad)[:n], None


def _flash_fwd_impl(static, q, k, v):
    return _flash_run(static, q, k, v, want_lse=False)[0]


def _flash_fused_fwd(static, q, k, v):
    return _flash_run(static, q, k, v, want_lse=True)


def _flash_fused_bwd(static, res, tensors, dout):
    cfg, causal, window = static
    o_rows, lse = res
    q, k, v = tensors
    n = q.shape[0]
    s = k.shape[0]
    h_k = k.shape[1]
    q_rows, k_t, v_t, kw, n_pad = _flash_layouts(cfg, q, k, v)
    do_rows = _ref.rows_from_heads(_pad_tokens(dout, n_pad), h_k)
    delta = _delta_panels(do_rows, o_rows)
    dq_rows = _flash.flash_attention_dq(q_rows, k_t, v_t, do_rows, lse, delta,
                                        causal=causal, window=window, **kw)
    dk_t, dv_t = _flash.flash_attention_dkv(q_rows, k_t, v_t, do_rows, lse,
                                            delta, causal=causal,
                                            window=window, **kw)
    dq = _ref.heads_from_rows(dq_rows, n_pad)[:n].astype(q.dtype)
    dk = dk_t[:, :s].transpose(1, 0, 2).astype(k.dtype)
    dv = dv_t[:, :s].transpose(1, 0, 2).astype(v.dtype)
    return dq, dk, dv


def _flash_twin(static, q, k, v):
    _, causal, window = static
    return _ref.flash_ref_chunked(q, k, v, causal=causal, window=window)


_flash_op = kernel_vjp(_flash_fwd_impl, _flash_twin, num_diff=3,
                       fused_fwd=_flash_fused_fwd,
                       fused_bwd=_flash_fused_bwd)


def flash_attention(q, k, v, cfg: NSAConfig, *, causal: bool = True,
                    window: int | None = None):
    """Pallas flash attention (full or sliding-window)."""
    return _flash_op((cfg, causal, window), q, k, v)


# =====================================================================
# paged decode: shared compressed prologue + kernel / gather organizations
# =====================================================================
def _paged_sel_win_ref(q, k_pages, v_pages, page_table, idx, valid, pos,
                       cfg: NSAConfig):
    """Gather-through-page-table reference for ONE slot's selected + sliding
    branches.  q: (h, d); idx/valid: (h_k, T); pos: scalar.
    Returns (out_sel, out_win): each (h, dv) float32.
    """
    h, d = q.shape
    p_sz, h_k = k_pages.shape[1], k_pages.shape[2]
    g = h // h_k

    # --- selected branch: gather exactly the T physical pages per KV head
    #     (each head pulls only its own rows of its own pages) ---
    t = idx.shape[-1]
    phys = page_table[idx]                                  # (h_k, T)
    hk_i = jnp.arange(h_k)
    k_sel = jax.vmap(lambda ph, i: k_pages[ph, :, i])(phys, hk_i)
    v_sel = jax.vmap(lambda ph, i: v_pages[ph, :, i])(phys, hk_i)
    k_sel = k_sel.reshape(h_k, t * p_sz, d)                 # (h_k, T·P, d)
    v_sel = v_sel.reshape(h_k, t * p_sz, -1)
    tok_pos = (idx[..., None] * p_sz + jnp.arange(p_sz)).reshape(h_k, t * p_sz)
    sel_mask = jnp.repeat(valid, p_sz, axis=-1) & (tok_pos <= pos)
    qg = q.reshape(h_k, g, d).astype(jnp.float32)
    s_sel = jnp.einsum("kgd,ksd->kgs", qg, k_sel.astype(jnp.float32))
    s_sel = s_sel / jnp.sqrt(d).astype(jnp.float32)
    p_sel, _ = _safe_softmax(s_sel, sel_mask[:, None, :])
    out_sel = jnp.einsum("kgs,ksd->kgd", p_sel, v_sel.astype(jnp.float32))

    # --- sliding branch: the trailing window through the page table ---
    w = cfg.window_size
    win_rows = pos - (w - 1) + jnp.arange(w)
    k_win = gather_rows(k_pages, page_table, win_rows)      # (W, h_k, d)
    v_win = gather_rows(v_pages, page_table, win_rows)
    win_mask = (win_rows >= 0) & (win_rows <= pos)
    p_win, _ = _safe_softmax(_gqa_scores(q[None], k_win),
                             win_mask[None, None, :])
    out_win = _gqa_out(p_win, v_win)[0]
    return out_sel.reshape(h, -1), out_win


def paged_decode_attention(gates, q, k_pages, v_pages, page_tables,
                           cmp_k, cmp_v, pos, cfg: NSAConfig, *,
                           kernel: bool, block_s: int | None = None):
    """Batched multi-slot NSA decode reading KV through per-slot page tables —
    touches ONLY the pages the three branches address (page size == B_K, so
    one selected block is one physical page):

      compressed  all compressed-token rows (already gathered views — they
                  are O(N/stride) small)
      selected    the T pages named by ``page_table[idx]`` per slot
      sliding     the trailing ceil(W/B_K)+1 pages per slot

    gates: (B, h, 3); q: (B, h, d); k_pages/v_pages: (N_pages, P, h_k, d*);
    page_tables: (B, max_pages) int32; cmp_k/cmp_v: (B, N_cmp_max, h_k, d*);
    pos: (B,).  Returns (B, h, dv).

    ``kernel=True`` runs the Pallas paged-decode kernel: ``fsa_selected``'s
    BlockSpec pattern with the kv index_map composed through the page table
    (ids -> page_table[ids]) and B slots folded into the matmul M dimension —
    one launch per engine tick.  ``kernel=False`` is the gather reference
    (still a single batched dispatch, vmapped over slots).  The compressed
    prologue is shared with the dense-cache decode via
    ``sparse.decode_cmp_and_select`` on both paths.
    """
    b, h, d = q.shape
    p_sz, h_k = k_pages.shape[1], k_pages.shape[2]
    assert p_sz == cfg.block_size, "page size must equal the NSA block size"
    g = h // h_k
    s_max = page_tables.shape[1] * p_sz

    # --- compressed branch + top-T selection (shared with the dense path;
    #     logical block id == page-table index) ---
    out_cmp, idx, valid = jax.vmap(
        lambda q1, ck, cv, p1: sparse.decode_cmp_and_select(
            q1[None], ck, cv, p1, cfg, s_max))(q, cmp_k, cmp_v, pos)
    out_cmp = out_cmp[:, 0]                                  # (B, h, dv)
    idx, valid = idx[:, 0], valid[:, 0]                      # (B, h_k, T)

    if kernel:
        bs = block_s or cfg.paged_slot_block or max(1, -(-8 // g))
        bs = min(bs, b)
        pad = (-b) % bs
        if pad:
            q_p = jnp.pad(q, ((0, pad), (0, 0), (0, 0)))
            tables_p = jnp.pad(page_tables, ((0, pad), (0, 0)))
            idx_p = jnp.pad(idx, ((0, pad), (0, 0), (0, 0)))
            valid_p = jnp.pad(valid, ((0, pad), (0, 0), (0, 0)))
            pos_p = jnp.pad(pos, ((0, pad),))
        else:
            q_p, tables_p, idx_p, valid_p, pos_p = (q, page_tables, idx,
                                                    valid, pos)
        bp = b + pad
        pages, blks = _paged.build_decode_steps(
            idx_p, valid_p, tables_p, pos_p, window=cfg.window_size,
            page_size=p_sz, block_s=bs)
        q_rows = (q_p.reshape(bp, h_k, g, d).transpose(1, 0, 2, 3)
                     .reshape(h_k, bp * g, d))
        o_sel, o_win = _paged.paged_decode(
            q_rows, k_pages, v_pages, pages, blks, pos_p.astype(jnp.int32),
            g=g, block_s=bs, num_sel=idx.shape[-1], window=cfg.window_size,
            interpret=cfg.interpret)
        dv = o_sel.shape[-1]
        unfold = lambda o: (o.reshape(h_k, bp, g, dv).transpose(1, 0, 2, 3)
                             .reshape(bp, h, dv)[:b])
        out_sel, out_win = unfold(o_sel), unfold(o_win)
    else:
        out_sel, out_win = jax.vmap(
            lambda q1, tb, i1, v1, p1: _paged_sel_win_ref(
                q1, k_pages, v_pages, tb, i1, v1, p1, cfg))(
                    q, page_tables, idx, valid, pos)

    gf = gates.astype(jnp.float32)
    out = (gf[..., 0:1] * out_cmp.astype(jnp.float32)
           + gf[..., 1:2] * out_sel
           + gf[..., 2:3] * out_win)
    return out.astype(q.dtype)


# =====================================================================
# backend registrations
# =====================================================================
def _kernel_nsa(params, gates, q, k, v, cfg, kernel, q_chunk):
    """Three-branch NSA with the selected branch on a Pallas kernel and the
    sliding branch on the Pallas flash kernel (the old impl="kernel")."""
    out_cmp, idx, valid = core_attn.compressed_and_selection(
        params, q, k, v, cfg, q_chunk=q_chunk)
    out_sel = selected_attention(q, k, v, idx, valid, cfg, kernel=kernel)
    out_win = flash_attention(q, k, v, cfg, causal=True,
                              window=cfg.window_size)
    gf = gates.astype(jnp.float32)
    out = (gf[..., 0:1] * out_cmp.astype(jnp.float32)
           + gf[..., 1:2] * out_sel.astype(jnp.float32)
           + gf[..., 2:3] * out_win.astype(jnp.float32))
    return out.astype(q.dtype)


def _register_selected_kernel_backend(name, caps):
    @register_backend(name, capabilities=caps)
    def backend(params, gates, q, k, v, cache, cfg, mode,
                q_chunk: int = 512, **kw):
        return _kernel_nsa(params, gates, q, k, v, cfg, name, q_chunk)
    return backend


_register_selected_kernel_backend("fsa", Capabilities(
    modes=("train", "prefill"), algorithms=("nsa",), differentiable=True,
    fused_backward=True, priority=60, preferred_platforms=("tpu",)))

_register_selected_kernel_backend("fsa_faithful", Capabilities(
    modes=("train", "prefill"), algorithms=("nsa",), differentiable=True,
    fused_backward=True, priority=40, preferred_platforms=("tpu",)))

# The vanilla-NSA loop order keeps one query row per (token, head) in the
# MXU M dim, so it only fills the matmul when the GQA group is wide: the
# paper's regime analysis (and our analytic model) put its win at g >= 8.
_register_selected_kernel_backend("nsa", Capabilities(
    modes=("train", "prefill"), algorithms=("nsa",), differentiable=True,
    min_g=8, priority=20, preferred_platforms=("tpu",)))


@register_backend("sparse_union", capabilities=Capabilities(
    modes=("train", "prefill"), algorithms=("nsa",), differentiable=True,
    priority=70))
def _sparse_union_backend(params, gates, q, k, v, cache, cfg, mode,
                          q_chunk: int = 512, **kw):
    return sparse.nsa_attention_sparse(
        params, gates, q, k, v, cfg, q_chunk=q_chunk,
        selected_fn=sparse.selected_union_attention)


@register_backend("sparse_gather", capabilities=Capabilities(
    modes=("train", "prefill", "decode"), algorithms=("nsa",),
    differentiable=True, priority=20))
def _sparse_gather_backend(params, gates, q, k, v, cache, cfg, mode,
                           q_chunk: int = 512, **kw):
    if mode == "decode":
        return sparse.nsa_decode_step(params, gates, q, k, v,
                                      cache["cmp_k"], cache["cmp_v"],
                                      cache["pos"], cfg)
    return sparse.nsa_attention_sparse(
        params, gates, q, k, v, cfg, q_chunk=q_chunk,
        selected_fn=sparse.selected_gather_attention)


@register_backend("flash_full", capabilities=Capabilities(
    modes=("train", "prefill"), algorithms=("full",), differentiable=True,
    fused_backward=True, priority=5, preferred_platforms=("tpu",)))
def _flash_full_backend(params, gates, q, k, v, cache, cfg, mode,
                        causal: bool = True, **kw):
    return flash_attention(q, k, v, cfg, causal=causal, window=None)


@register_backend("flash_sliding", capabilities=Capabilities(
    modes=("train", "prefill"), algorithms=("sliding",), differentiable=True,
    fused_backward=True, priority=5, preferred_platforms=("tpu",)))
def _flash_sliding_backend(params, gates, q, k, v, cache, cfg, mode,
                           window: int | None = None, **kw):
    return flash_attention(q, k, v, cfg, causal=True,
                           window=window or cfg.window_size)


@register_backend("paged_kernel", capabilities=Capabilities(
    modes=("paged_decode",), algorithms=("nsa",), paged=True, priority=50))
def _paged_kernel_backend(params, gates, q, k, v, cache, cfg, mode,
                          block_s: int | None = None, **kw):
    return paged_decode_attention(
        gates, q, k, v, cache["page_tables"], cache["cmp_k"], cache["cmp_v"],
        cache["pos"], cfg, kernel=True, block_s=block_s)


@register_backend("paged_gather", capabilities=Capabilities(
    modes=("paged_decode",), algorithms=("nsa",), paged=True, priority=20))
def _paged_gather_backend(params, gates, q, k, v, cache, cfg, mode,
                          block_s: int | None = None, **kw):
    return paged_decode_attention(
        gates, q, k, v, cache["page_tables"], cache["cmp_k"], cache["cmp_v"],
        cache["pos"], cfg, kernel=False, block_s=block_s)


def sparse_selected_fn(cfg: NSAConfig):
    """The sparse selected-branch organization the policy names — for code
    (e.g. paged chunked prefill) that runs the sparse NSA chunk machinery
    directly and needs the union/gather choice without string dispatch of
    its own."""
    if cfg.policy.backend == "sparse_gather":
        return sparse.selected_gather_attention
    return sparse.selected_union_attention


def _reference_decode(params, gates_t, q_t, k_cache, v_cache, cache, cfg):
    """Dense-oracle one-token decode: embed the query at row ``pos`` of a
    full-shape sequence and run the dense NSA oracle.  Recomputes the
    compression caches from the raw KV (independent of the incremental
    cmp-cache emission the fast paths maintain), so it cross-checks them.
    """
    pos = cache["pos"]
    s = k_cache.shape[0]
    q_full = jnp.zeros((s,) + q_t.shape, q_t.dtype).at[pos].set(q_t)
    g_full = jnp.zeros((s,) + gates_t.shape, jnp.float32).at[pos].set(
        gates_t.astype(jnp.float32))
    out = nsa_attention_ref(params, g_full, q_full, k_cache, v_cache, cfg)
    return jnp.take(out, pos, axis=0).astype(q_t.dtype)


@register_backend("reference", capabilities=Capabilities(
    modes=("train", "prefill", "decode", "paged_decode"),
    algorithms=("nsa", "full", "sliding"), differentiable=True, paged=True,
    priority=10))
def _reference_backend(params, gates, q, k, v, cache, cfg, mode,
                       algorithm: str = "nsa", causal: bool = True,
                       window: int | None = None, q_chunk: int = 512, **kw):
    if algorithm == "full":
        return _ref.flash_ref_chunked(q, k, v, causal=causal, q_chunk=q_chunk)
    if algorithm == "sliding":
        return _ref.flash_ref_chunked(q, k, v, causal=True,
                                      window=window or cfg.window_size,
                                      q_chunk=q_chunk)
    if mode == "decode":
        return _reference_decode(params, gates, q, k, v, cache, cfg)
    if mode == "paged_decode":
        # gather full dense views through the page tables, then run the
        # dense-cache decode path on them — checks the paged organizations
        # at a different gather granularity (whole view vs selected pages)
        tables, pos = cache["page_tables"], cache["pos"]
        s_max = tables.shape[1] * k.shape[1]
        rows = jnp.arange(s_max)
        k_view = jax.vmap(gather_rows, in_axes=(None, 0, None))(k, tables, rows)
        v_view = jax.vmap(gather_rows, in_axes=(None, 0, None))(v, tables, rows)
        return jax.vmap(
            lambda g1, q1, kv1, vv1, ck, cv, p1: sparse.nsa_decode_step(
                params, g1, q1, kv1, vv1, ck, cv, p1, cfg))(
                    gates, q, k_view, v_view, cache["cmp_k"], cache["cmp_v"],
                    pos)
    return nsa_attention_ref(params, gates, q, k, v, cfg)
