"""Capability-based attention backend registry.

Every attention implementation in the repo registers here under a unique
name with a declared :class:`Capabilities` record.  Callers never dispatch
on strings or bools themselves: they describe *what they need* as an
:class:`AttentionRequest` and :func:`resolve` returns the best capable
backend — or raises a :class:`BackendResolutionError` that names the
capable alternatives.

This is the FSA/NSA thesis turned into an API: multiple kernel
organizations of the same math win in different regimes (GQA group size
``g``, sequence length, platform), so the *selection* of an organization is
data, not code scattered over if/elif ladders.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Protocol, runtime_checkable

from repro.telemetry import metrics as _metrics

MODES = ("train", "prefill", "decode", "paged_decode")
ALGORITHMS = ("nsa", "full", "sliding")


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What a backend can do.  ``resolve`` only ever picks a backend whose
    capabilities cover the request; an explicit backend request that falls
    outside its capabilities is a structured error, not a silent fallback."""

    modes: tuple = ("train", "prefill")   # subset of MODES
    algorithms: tuple = ("nsa",)          # subset of ALGORITHMS
    differentiable: bool = False          # safe under jax.grad (custom VJP ok)
    fused_backward: bool = False          # backward is a fused Pallas kernel
                                          # (not the XLA-twin fallback)
    min_g: int = 1                        # supported GQA group-size range
    max_g: Optional[int] = None
    paged: bool = False                   # reads KV through page tables
    interpret_ok: bool = True             # runs in Pallas interpret mode (CPU)
    priority: int = 0                     # auto-resolve score (higher wins)
    preferred_platforms: tuple = ()       # +100 priority on these platforms

    def describe(self) -> str:
        bits = [f"modes={'|'.join(self.modes)}",
                f"alg={'|'.join(self.algorithms)}"]
        if self.differentiable:
            bits.append("grad")
        if self.fused_backward:
            bits.append("fused-bwd")
        if self.min_g > 1 or self.max_g is not None:
            bits.append(f"g∈[{self.min_g},{self.max_g or '∞'}]")
        if self.paged:
            bits.append("paged")
        if not self.interpret_ok:
            bits.append("tpu-only")
        return ", ".join(bits)


@dataclasses.dataclass(frozen=True)
class AttentionRequest:
    """Shape/mode description a backend must cover.

    ``seq_len`` is the KV span (0 = unknown/irrelevant); ``g`` the GQA group
    size; ``needs_grad`` whether the call sits under ``jax.grad``;
    ``paged`` whether KV lives in paged storage; ``interpret`` whether the
    call must run without a TPU (Pallas interpret mode); ``platform`` the
    jax default backend ("cpu"/"tpu"/"gpu")."""

    mode: str = "prefill"
    algorithm: str = "nsa"
    seq_len: int = 0
    g: int = 1
    needs_grad: bool = False
    paged: bool = False
    interpret: bool = True
    platform: str = "cpu"


@runtime_checkable
class AttentionBackend(Protocol):
    """A registered implementation: a callable with ``name`` and
    ``capabilities`` attributes.  Call signature (all backends)::

        backend(params, gates, q, k, v, cache, cfg, mode, **kw)

    ``params``/``gates`` are the NSA compression/gate parameters (None for
    non-NSA algorithms); ``k``/``v`` are the raw KV storage (dense arrays or
    page pools); ``cache`` carries mode-specific auxiliary state (cmp caches,
    page tables, positions)."""

    name: str
    capabilities: Capabilities

    def __call__(self, params, gates, q, k, v, cache, cfg, mode, **kw): ...


class BackendResolutionError(ValueError):
    """No (capable) backend for a request.  Carries the requested name, the
    request, the rejection reason, the names of capable alternatives, and —
    when nothing is capable — the nearest misses: the backends failing the
    fewest capability criteria, with their first failing reason each, so
    the error names what to change instead of just what went wrong."""

    def __init__(self, requested: str, request: AttentionRequest,
                 reason: str, alternatives: tuple, near_misses: tuple = ()):
        self.requested = requested
        self.request = request
        self.reason = reason
        self.alternatives = tuple(alternatives)
        self.near_misses = tuple(near_misses)
        if self.alternatives:
            alt = (f" Capable backends for this request: "
                   f"{', '.join(self.alternatives)}.")
        else:
            alt = " No registered backend covers this request."
            if self.near_misses:
                misses = "; ".join(f"{n}: {r}" for n, r in self.near_misses)
                alt += (f" Nearest misses — {misses}."
                        f" (repro.attention.explain(cfg, request) prints the"
                        f" full capability table.)")
        super().__init__(
            f"attention backend '{requested}' cannot serve "
            f"mode={request.mode}/algorithm={request.algorithm} "
            f"(g={request.g}, seq_len={request.seq_len}, "
            f"needs_grad={request.needs_grad}, paged={request.paged}, "
            f"platform={request.platform}): {reason}.{alt}")


_REGISTRY: dict = {}


def register_backend(name: str, *, capabilities: Capabilities) -> Callable:
    """Decorator: register ``fn`` as attention backend ``name``."""

    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"attention backend '{name}' already registered")
        fn.name = name
        fn.capabilities = capabilities
        _REGISTRY[name] = fn
        return fn

    return deco


def get_backend(name: str) -> AttentionBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown attention backend '{name}'; registered: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def list_backends() -> dict:
    """name -> Capabilities for every registered backend (sorted by name)."""
    return {n: _REGISTRY[n].capabilities for n in sorted(_REGISTRY)}


def unsupported_reasons(caps: Capabilities,
                        req: AttentionRequest) -> tuple:
    """Every criterion of ``req`` that ``caps`` fails (empty = capable)."""
    reasons = []
    if req.mode not in caps.modes:
        reasons.append(f"mode '{req.mode}' not in declared modes {caps.modes}")
    if req.algorithm not in caps.algorithms:
        reasons.append(f"algorithm '{req.algorithm}' not in declared "
                       f"algorithms {caps.algorithms}")
    if req.needs_grad and not caps.differentiable:
        reasons.append(
            "not differentiable (no VJP), but gradients were requested")
    if req.g < caps.min_g:
        reasons.append(
            f"GQA group size g={req.g} below declared min_g={caps.min_g}")
    if caps.max_g is not None and req.g > caps.max_g:
        reasons.append(
            f"GQA group size g={req.g} above declared max_g={caps.max_g}")
    if req.paged and not caps.paged:
        reasons.append("does not read paged KV storage")
    if req.interpret and not caps.interpret_ok:
        reasons.append("requires compiled Pallas (no interpret-mode support)")
    return tuple(reasons)


def unsupported_reason(caps: Capabilities,
                       req: AttentionRequest) -> Optional[str]:
    """Why ``caps`` cannot serve ``req`` (None = it can; first reason)."""
    reasons = unsupported_reasons(caps, req)
    return reasons[0] if reasons else None


def capable_backends(req: AttentionRequest) -> tuple:
    """Names of all registered backends that can serve ``req``."""
    return tuple(n for n in sorted(_REGISTRY)
                 if unsupported_reason(_REGISTRY[n].capabilities, req) is None)


def near_misses(req: AttentionRequest, limit: int = 3) -> tuple:
    """((name, first reason), ...) for the backends failing the *fewest*
    capability criteria — the candidates a caller is closest to unlocking."""
    scored = []
    for n in sorted(_REGISTRY):
        reasons = unsupported_reasons(_REGISTRY[n].capabilities, req)
        if reasons:
            scored.append((len(reasons), n, reasons[0]))
    scored.sort()
    return tuple((n, r) for _, n, r in scored[:limit])


def explain(cfg, request: AttentionRequest, backend: str = "auto") -> str:
    """Human-readable capability table for ``request``: one row per
    registered backend with its auto-resolve score (capable) or its
    ``unsupported_reason`` (not capable), plus the backend ``resolve``
    would pick.  The debugging companion to
    :class:`BackendResolutionError`::

        print(repro.attention.explain(cfg, AttentionRequest(mode="train")))
    """
    rows = []
    for name in sorted(_REGISTRY):
        caps = _REGISTRY[name].capabilities
        reasons = unsupported_reasons(caps, request)
        if reasons:
            status = f"--    {'; '.join(reasons)}"
        else:
            status = f"OK    score={_score(caps, request)}"
        rows.append((name, caps.describe(), status))
    try:
        pick = f"resolve -> {resolve(cfg, request, backend).name}"
    except BackendResolutionError as e:
        pick = f"resolve -> FAILS: {e.reason}"
    w_name = max(len(r[0]) for r in rows)
    w_caps = max(len(r[1]) for r in rows)
    lines = [f"AttentionRequest(mode={request.mode}, "
             f"algorithm={request.algorithm}, g={request.g}, "
             f"seq_len={request.seq_len}, needs_grad={request.needs_grad}, "
             f"paged={request.paged}, interpret={request.interpret}, "
             f"platform={request.platform})",
             pick, ""]
    lines += [f"{n:<{w_name}}  [{c:<{w_caps}}]  {s}" for n, c, s in rows]
    return "\n".join(lines)


def _score(caps: Capabilities, req: AttentionRequest) -> int:
    score = caps.priority + (100 if req.platform in caps.preferred_platforms
                             else 0)
    # training under jax.grad: prefer backends whose backward pass is a fused
    # Pallas kernel over ones that pay the XLA-twin backward (the paper's
    # training-speedup claim lives in the backward)
    if req.mode == "train" and req.needs_grad and caps.fused_backward:
        score += 50
    return score


def resolve(cfg, request: AttentionRequest,
            backend: str = "auto") -> AttentionBackend:
    """Pick the backend for ``request``.

    Explicit ``backend`` names are honored iff capable (else a
    :class:`BackendResolutionError` naming capable alternatives).  For
    ``"auto"``, the mode's policy default (``cfg.policy``) is consulted
    first; if that is also "auto" the highest-scoring capable backend wins
    (platform preference included).  Below ``cfg.min_seq_for_sparse`` the
    dense ``reference`` fallback is picked for train/prefill NSA requests —
    selection is degenerate when the context is shorter than a handful of
    KV blocks, so sparsity cannot pay for its overhead there.
    """
    # decode-time paths exist only for the NSA cache layouts; a full/sliding
    # decode request is malformed, not merely unserved — fail it up front
    # rather than letting a backend crash on mismatched shapes
    if request.mode in ("decode", "paged_decode") and request.algorithm != "nsa":
        _record_fallback("error", request, requested=backend)
        raise BackendResolutionError(
            backend, request,
            f"mode '{request.mode}' is NSA-only (algorithm "
            f"'{request.algorithm}' has no cache-decode path)", ())

    # The policy's per-mode defaults name NSA organizations (that is what
    # KernelPolicy bundles); full/sliding requests never consult them — the
    # old cfg.kernel likewise only ever picked the NSA selected-branch
    # kernel, not the full/swa/cross-attention implementation.
    if backend == "auto" and cfg is not None and request.algorithm == "nsa":
        policy = getattr(cfg, "policy", None)
        if policy is not None:
            backend = {"train": policy.backend, "prefill": policy.backend,
                       "decode": policy.decode_backend,
                       "paged_decode": policy.paged_backend}[request.mode]

    # dense short-sequence fallback (algorithm spec, not a perf heuristic)
    if (cfg is not None and request.algorithm == "nsa"
            and request.mode in ("train", "prefill") and request.seq_len
            and request.seq_len < cfg.min_seq_for_sparse):
        if backend != "reference":
            _record_fallback("dense_short_seq", request, requested=backend)
        backend = "reference"

    if backend != "auto":
        b = get_backend(backend)
        reason = unsupported_reason(b.capabilities, request)
        if reason is not None:
            _record_fallback("error", request, requested=backend)
            raise BackendResolutionError(backend, request, reason,
                                         capable_backends(request),
                                         near_misses(request))
        return b

    names = capable_backends(request)
    if not names:
        _record_fallback("error", request, requested="auto")
        raise BackendResolutionError("auto", request,
                                     "no capable backend registered", (),
                                     near_misses(request))
    return _REGISTRY[max(
        names, key=lambda n: (_score(_REGISTRY[n].capabilities, request), n))]


def _record_fallback(kind: str, request: AttentionRequest, *,
                     requested: str) -> None:
    """Count + stream a resolution-fallback event (no-op when global
    telemetry is off)."""
    reg = _metrics.registry()
    reg.counter("attention_resolve_fallback_total", kind=kind,
                mode=request.mode).inc()
    reg.event("resolve_fallback", fallback=kind, requested=requested,
              mode=request.mode, algorithm=request.algorithm,
              seq_len=request.seq_len, g=request.g)
