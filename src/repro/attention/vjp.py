"""Shared custom-VJP scaffolding for kernel-forward / XLA-twin-backward ops.

Every Pallas forward kernel in this repo pairs with a *differentiable twin*
— the same math written in gather/einsum XLA ops — and the backward pass is
``jax.vjp`` through that twin.  The boilerplate (residual packing, float0
cotangents for integer/bool operands, nondiff static config) used to be
duplicated per op (``_sel_fwd/_sel_bwd``, ``_flash_fwd/_flash_bwd``); it
lives once here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def twin_vjp(fwd_impl, twin_impl, *, num_diff: int):
    """Build ``op(static, *tensors)`` with a custom VJP.

    ``fwd_impl(static, *tensors)`` runs the (non-differentiable) kernel
    forward; ``twin_impl(static, *tensors)`` is the XLA twin of identical
    math.  The first ``num_diff`` tensors receive real cotangents (via
    ``jax.vjp`` through the twin, rematerialized — nothing big is saved);
    the rest (selection indices, validity masks, positions) get ``float0``.

    ``static`` must be hashable (e.g. an ``NSAConfig`` or a tuple of
    hashables) — it is a ``nondiff_argnums`` argument.
    """

    @functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
    def op(static, *tensors):
        return fwd_impl(static, *tensors)

    def fwd(static, *tensors):
        return fwd_impl(static, *tensors), tensors

    def bwd(static, tensors, dout):
        diff, nondiff = tensors[:num_diff], tensors[num_diff:]
        _, pullback = jax.vjp(
            lambda *d: twin_impl(static, *d, *nondiff), *diff)
        grads = pullback(dout)
        zeros = tuple(jnp.zeros(t.shape, jax.dtypes.float0) for t in nondiff)
        return grads + zeros

    op.defvjp(fwd, bwd)
    return op
