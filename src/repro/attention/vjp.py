"""Shared custom-VJP scaffolding for Pallas-forward attention ops.

Every Pallas forward kernel in this repo pairs with a *differentiable twin*
— the same math written in gather/einsum XLA ops.  Historically the backward
pass was always ``jax.vjp`` through that twin; :func:`kernel_vjp` now also
accepts a *fused* backward (Pallas dQ/dK/dV kernels driven by residuals the
forward packs — typically ``(out, lse)`` à la flash attention) and uses the
twin only as the fallback for configurations the fused path does not cover.

The boilerplate (residual packing, float0 cotangents for integer/bool
operands, nondiff static config) used to be duplicated per op
(``_sel_fwd/_sel_bwd``, ``_flash_fwd/_flash_bwd``); it lives once here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def kernel_vjp(fwd_impl, twin_impl, *, num_diff: int,
               fused_fwd=None, fused_bwd=None):
    """Build ``op(static, *tensors)`` with a custom VJP.

    ``fwd_impl(static, *tensors)`` runs the (non-differentiable) kernel
    forward; ``twin_impl(static, *tensors)`` is the XLA twin of identical
    math.  The first ``num_diff`` tensors receive real cotangents; the rest
    (selection indices, validity masks, positions) get ``float0``.

    With only the twin, the backward is ``jax.vjp`` through ``twin_impl``
    (rematerialized — nothing big is saved).  A backend that declares a
    fused backward additionally supplies:

    * ``fused_fwd(static, *tensors) -> (out, residuals)`` — the kernel
      forward that also emits backward residuals (out/lse in kernel
      layouts).  Returning ``residuals=None`` opts this configuration out:
      the backward falls back to the twin (e.g. a selected-branch kernel
      name without a fused dQ/dKV implementation).
    * ``fused_bwd(static, residuals, tensors, dout) -> grads`` — returns
      cotangents for the first ``num_diff`` tensors.

    ``residuals is None`` is pytree *structure*, so the twin-vs-fused branch
    is resolved at trace time per ``static`` — no runtime cond.

    ``static`` must be hashable (e.g. an ``NSAConfig`` or a tuple of
    hashables) — it is a ``nondiff_argnums`` argument.
    """

    @functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
    def op(static, *tensors):
        return fwd_impl(static, *tensors)

    def fwd(static, *tensors):
        if fused_fwd is None:
            return fwd_impl(static, *tensors), (None, tensors)
        out, residuals = fused_fwd(static, *tensors)
        return out, (residuals, tensors)

    def bwd(static, pack, dout):
        residuals, tensors = pack
        diff, nondiff = tensors[:num_diff], tensors[num_diff:]
        if residuals is None:
            _, pullback = jax.vjp(
                lambda *d: twin_impl(static, *d, *nondiff), *diff)
            grads = tuple(pullback(dout))
        else:
            grads = tuple(fused_bwd(static, residuals, tensors, dout))
        zeros = tuple(jnp.zeros(t.shape, jax.dtypes.float0) for t in nondiff)
        return grads + zeros

    op.defvjp(fwd, bwd)
    return op


def twin_vjp(fwd_impl, twin_impl, *, num_diff: int):
    """Kernel forward + XLA-twin backward (no fused path). Compat wrapper."""
    return kernel_vjp(fwd_impl, twin_impl, num_diff=num_diff)
