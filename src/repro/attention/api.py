"""The single public attention entry point.

``nsa_attention`` covers every mode the repo serves — training / prefill
over a full sequence, dense-cache decode, and paged (serving) decode — and
every registered organization of the math.  Callers describe the request;
:func:`repro.attention.registry.resolve` picks the backend.

Shapes by mode (all unbatched over the slot/batch axis unless noted):

  train/prefill   q: (N, h, d);  k/v: (S, h_k, d);  cache unused
  decode          q: (h, d);     k/v: dense caches (S, h_k, d);
                  cache = {"cmp_k", "cmp_v", "pos"}
  paged_decode    q: (B, h, d);  k/v: page pools (P, page, h_k, d);
                  cache = {"page_tables", "cmp_k", "cmp_v", "pos"}  (batched)

``algorithm`` selects the math: "nsa" (three-branch NSA, needs
``params``/``gates``), "full" or "sliding" (plain attention; ``params``/
``gates`` may be None).
"""
from __future__ import annotations

import jax

from repro.attention.backends import SELECTED_KERNELS
from repro.attention.registry import AttentionRequest, resolve
from repro.core.nsa_config import SELECTED_IMPL_TO_BACKEND
from repro.telemetry import metrics as _metrics
from repro.telemetry import trace as _trace

# legacy ``ModelConfig.attn_impl`` spellings accepted as backend names;
# derived from the registry sources so new backends stay in sync
_SPARSE_NAMES = tuple(SELECTED_IMPL_TO_BACKEND.values())
_KERNEL_NAMES = SELECTED_KERNELS


def normalize_backend_name(backend: str, cfg) -> str:
    """Map legacy impl aliases ("sparse"/"kernel"/"gather") onto registry
    names, consulting the policy for the sub-choice they used to imply."""
    if backend == "sparse":
        b = cfg.policy.backend
        return b if b in _SPARSE_NAMES else "sparse_union"
    if backend == "gather":
        return "sparse_gather"
    if backend == "kernel":
        b = cfg.policy.backend
        return b if b in _KERNEL_NAMES else "fsa"
    return backend


def nsa_attention(params, gates, q, k, v, cache=None, *, cfg,
                  mode: str = "prefill", backend: str = "auto",
                  algorithm: str = "nsa", causal: bool = True,
                  window: int | None = None, q_chunk: int = 512,
                  block_s: int | None = None,
                  needs_grad: bool | None = None):
    """Attention through the capability-based backend registry.

    ``backend="auto"`` consults ``cfg.policy`` and then picks the best
    capable backend for the shape/mode/platform; explicit names are honored
    iff capable (else :class:`BackendResolutionError` names the capable
    alternatives).  One algorithm-spec exception: NSA train/prefill requests
    below ``cfg.min_seq_for_sparse`` run the dense ``reference`` fallback
    even for explicit backends — selection is degenerate at a handful of KV
    blocks (historical ``nsa_attention(impl=)`` behavior, kept).
    ``needs_grad`` defaults to True for mode="train".
    """
    if mode in ("train", "prefill"):
        seq_len, g = q.shape[0], q.shape[1] // k.shape[1]
    elif mode == "decode":
        seq_len, g = k.shape[0], q.shape[0] // k.shape[1]
    elif mode == "paged_decode":
        seq_len, g = 0, q.shape[1] // k.shape[2]
    else:
        raise ValueError(f"unknown attention mode: {mode}")

    request = AttentionRequest(
        mode=mode, algorithm=algorithm, seq_len=seq_len, g=g,
        needs_grad=(mode == "train") if needs_grad is None else needs_grad,
        paged=(mode == "paged_decode"), interpret=cfg.interpret,
        platform=jax.default_backend())
    fn = resolve(cfg, request, normalize_backend_name(backend, cfg))
    # dispatch accounting: one counter bump + one span per *python-level*
    # call (under jit that is once per trace, which is what "which backend
    # did resolve pick, how often" means — executed-dispatch timing lives in
    # the engine's tick spans / the profiler's named kernel scopes)
    _metrics.registry().counter("attention_dispatch_total", backend=fn.name,
                                mode=mode, algorithm=algorithm).inc()
    with _trace.span("attention.dispatch", backend=fn.name, mode=mode):
        return fn(params, gates, q, k, v, cache, cfg, mode,
                  algorithm=algorithm, causal=causal, window=window,
                  q_chunk=q_chunk, block_s=block_s)
