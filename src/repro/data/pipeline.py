"""Deterministic synthetic LM data pipeline with step-indexed resume.

Every batch is a pure function of (seed, step), so a restarted job resumes
bit-identically from any checkpoint step without replaying the stream — the
property fault-tolerant training needs.  The synthetic stream is a mixture of
Zipfian unigrams and short copy motifs, giving a learnable (non-uniform)
distribution so loss curves are meaningful (paper Fig. 10 analogue).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 1024
    seq_len: int = 256
    global_batch: int = 8
    seed: int = 0
    zipf_alpha: float = 1.1
    motif_len: int = 16          # copy-motif span (gives in-context structure)


def _zipf_logits(vocab: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1)
    return np.log(ranks ** (-alpha))


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._logits = jnp.asarray(_zipf_logits(cfg.vocab, cfg.zipf_alpha),
                                   jnp.float32)

    def batch_at(self, step: int) -> dict:
        """Batch for a given step (deterministic, resumable)."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        toks = jax.random.categorical(
            k1, jnp.broadcast_to(self._logits, (cfg.global_batch, cfg.seq_len,
                                                cfg.vocab)))
        # splice copy motifs: second half repeats a span from the first half
        m = cfg.motif_len
        if cfg.seq_len >= 4 * m:
            src = jax.random.randint(k2, (cfg.global_batch,), 0,
                                     cfg.seq_len // 2 - m)
            dst = jax.random.randint(k3, (cfg.global_batch,),
                                     cfg.seq_len // 2, cfg.seq_len - m)
            idx = jnp.arange(m)
            def splice(t, s, d):
                return jax.lax.dynamic_update_slice(
                    t, jax.lax.dynamic_slice(t, (s,), (m,)), (d,))
            toks = jax.vmap(splice)(toks, src, dst)
        toks = toks.astype(jnp.int32)
        labels = jnp.concatenate(
            [toks[:, 1:], jnp.full((cfg.global_batch, 1), -100, jnp.int32)],
            axis=1)
        return {"tokens": toks, "labels": labels}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def pack_documents(docs: list[np.ndarray], seq_len: int, pad_id: int = 0):
    """Greedy sequence packing of variable-length docs into fixed windows.
    Returns (tokens, segment_ids) — segment ids let attention mask across
    document boundaries."""
    rows, segs = [], []
    cur, cur_seg, seg_id = [], [], 1
    for d in docs:
        d = list(d)
        while d:
            space = seq_len - len(cur)
            take, d = d[:space], d[space:]
            cur += take
            cur_seg += [seg_id] * len(take)
            if len(cur) == seq_len:
                rows.append(cur)
                segs.append(cur_seg)
                cur, cur_seg = [], []
        seg_id += 1
    if cur:
        pad = seq_len - len(cur)
        rows.append(cur + [pad_id] * pad)
        segs.append(cur_seg + [0] * pad)
    return np.asarray(rows, np.int32), np.asarray(segs, np.int32)
