"""repro.data — deterministic synthetic pipeline + packing."""
from repro.data.pipeline import DataConfig, SyntheticLM, pack_documents
