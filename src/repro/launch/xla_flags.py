"""Production XLA flags for TPU jobs (compute/communication overlap).

These are applied by the real-cluster launcher (they are TPU-backend flags;
the CPU dry-run ignores them).  They enable the latency-hiding scheduler and
async collective fusion so the per-layer TP/SP collectives emitted by our
sharding constraints overlap with the surrounding matmuls — the automatic
counterpart of parallel/collective_matmul.py.
"""

TPU_PERF_FLAGS = " ".join([
    "--xla_tpu_enable_async_collective_fusion=true",
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
    "--xla_tpu_enable_async_collective_fusion_multiple_steps=true",
    "--xla_tpu_overlap_compute_collective_tc=true",
    "--xla_enable_async_all_gather=true",
    "--xla_enable_async_collective_permute=true",
    "--xla_tpu_spmd_threshold_for_allgather_cse=10000",
    "--xla_tpu_data_parallel_opt_different_sized_ops=true",
])


def apply(extra: str = ""):
    import os

    os.environ["XLA_FLAGS"] = " ".join(
        x for x in (os.environ.get("XLA_FLAGS", ""), TPU_PERF_FLAGS, extra)
        if x)
