import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init) — hence no `from __future__` in this module.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this script:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. lowers ``train_step`` (train/prefill shapes) or ``serve_step``
     (decode shapes) against ShapeDtypeStruct inputs (no allocation),
  3. compiles, prints ``memory_analysis()`` (proves it fits) and
     ``cost_analysis()`` (FLOPs/bytes for the roofline),
  4. parses the post-SPMD HLO for collective operand bytes,
  5. writes a JSON record to experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch codeqwen1.5-7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, all_configs, get_config
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import build, input_specs, supports_shape
from repro.optim import AdamWConfig, opt_state_specs
from repro.parallel import partition

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

def ns(tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg_overrides: dict | None = None, verbose: bool = True,
               num_microbatches: int | None = None):
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = supports_shape(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why,
                "mesh": "2x16x16" if multi_pod else "16x16"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build(cfg)
    t0 = time.time()

    with mesh_context(mesh):
        batch = input_specs(cfg, shape)
        if shape.mode == "prefill":
            # serving prefill: populate decode caches from the prompt batch
            # (VLM prompts carry an image-token prefix in the cache)
            extra = cfg.n_img_tokens if cfg.family == "vlm" else 0
            params_shape = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            pspecs = partition.param_specs(params_shape, mesh)
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch,
                                         shape.seq_len + extra))
            cspecs = partition.cache_specs_tree(cache_shape, mesh)
            jitted = jax.jit(
                lambda p, c, b: model.prefill(p, c, b),
                in_shardings=(ns(pspecs, mesh), ns(cspecs, mesh),
                              ns(partition.batch_specs(batch, mesh), mesh)),
                out_shardings=(None, ns(cspecs, mesh)),
                donate_argnums=(1,))
            lowered = jitted.lower(params_shape, cache_shape, batch)
        elif shape.mode == "train":
            opt_cfg = AdamWConfig()
            params_shape = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            pspecs = partition.param_specs(params_shape, mesh)
            state_specs = {"params": pspecs,
                           "opt": opt_state_specs(pspecs, opt_cfg)}
            from repro.optim import init_opt_state
            state_shape = jax.eval_shape(
                lambda p: {"params": p, "opt": init_opt_state(p, opt_cfg)},
                params_shape)
            batch_specs = partition.batch_specs(batch, mesh)
            # gradient accumulation: keep ~2 sequences per device per microbatch
            dp = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
            per_dev = max(1, shape.global_batch // dp)
            micro = num_microbatches or max(1, min(8, per_dev // 2))
            while shape.global_batch % (micro * dp) and micro > 1:
                micro -= 1
            step = make_train_step(cfg, mesh, opt_cfg, num_microbatches=micro)
            jitted = jax.jit(
                step,
                in_shardings=(ns(state_specs, mesh), ns(batch_specs, mesh)),
                out_shardings=(ns(state_specs, mesh), None),
                donate_argnums=(0,))
            lowered = jitted.lower(state_shape, batch)
        else:  # decode
            params_shape = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            pspecs = partition.param_specs(params_shape, mesh)
            cache_shape = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cspecs = partition.cache_specs_tree(cache_shape, mesh)
            step = make_serve_step(cfg, mesh)
            jitted = jax.jit(
                step,
                in_shardings=(ns(pspecs, mesh), ns(cspecs, mesh),
                              ns(partition.batch_specs(batch["tokens"], mesh), mesh),
                              None),
                out_shardings=(None, ns(cspecs, mesh)),
                donate_argnums=(1,))
            lowered = jitted.lower(params_shape, cache_shape,
                                   batch["tokens"], batch["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    t2 = time.time()
    hlo = compiled.as_text()
    corrected = hlo_analysis.analyze(hlo)      # trip-count-corrected, per device
    t_analyze = time.time() - t2
    coll = corrected["collectives"]
    n_dev = mesh.devices.size

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape.mode, "devices": n_dev,
        "num_microbatches": locals().get("micro", 1),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "analyze_s": round(t_analyze, 1),
        # raw XLA numbers (loop bodies counted once — kept for reference)
        "xla_flops_per_device": float(cost.get("flops", -1)),
        "xla_bytes_per_device": float(cost.get("bytes accessed", -1)),
        # trip-count-corrected numbers (see launch/hlo_analysis.py)
        "flops_per_device": corrected["flops"],
        "bytes_per_device": corrected["bytes"],
        "collective_bytes_per_device": coll,
        "trip_count_unknown": corrected.get("trip_count_unknown", False),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {rec['mesh']}: "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s")
        print(f"  memory_analysis: {rec['memory']}")
        print(f"  corrected: flops/dev={rec['flops_per_device']:.3e} "
              f"bytes/dev={rec['bytes_per_device']:.3e} "
              f"(xla once-counted: {rec['xla_flops_per_device']:.3e})")
        print(f"  collectives: { {k: (f'{v:.3e}' if isinstance(v, float) else v) for k, v in coll.items()} }")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--attn-impl", default=None,
                    help="override cfg.attn_impl: 'auto' or any "
                         "repro.attention registry backend name (legacy "
                         "'sparse'/'kernel' aliases still resolve)")
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--set", action="append", default=[],
                    help="generic ModelConfig override key=value (python "
                         "literal), e.g. --set remat=False")
    ap.add_argument("--micro", type=int, default=None,
                    help="override num_microbatches")
    ap.add_argument("--tag", default=None,
                    help="write result as <tag>.json (perf experiments)")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = list(all_configs()) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    overrides = {}
    if args.attn_impl:
        overrides["attn_impl"] = args.attn_impl
    if args.q_chunk:
        overrides["q_chunk"] = args.q_chunk
    import ast
    for kv in args.set:
        key, val = kv.split("=", 1)
        try:
            overrides[key] = ast.literal_eval(val)
        except (ValueError, SyntaxError):
            overrides[key] = val

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = args.tag or f"{arch}_{shape}_{'mp' if mp else 'sp'}"
                path = outdir / f"{tag}.json"
                if path.exists():
                    print(f"[dryrun] skip existing {tag}")
                    continue
                try:
                    rec = lower_cell(arch, shape, mp, overrides or None,
                                     num_microbatches=args.micro)
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    print(f"[dryrun] FAIL {tag}: {rec['error']}")
                path.write_text(json.dumps(rec, indent=1))
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
