"""Serving driver — thin CLI + back-compat wrapper over ``repro.serving``.

The real engine lives in ``repro.serving.Engine``: paged NSA KV-cache,
continuous batching, variable-length prompts, per-slot positions, slot
recycling.  This module keeps the historical ``Engine``/``Request`` API
(fixed request list, greedy decode of N tokens) for existing callers and
adds a dense fallback loop for recurrent/encdec families whose state is not
paged KV.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import build
from repro.serving import Engine as PagedEngine
from repro.serving import Request as ServeRequest
from repro.serving.engine import SUPPORTED_FAMILIES


@dataclasses.dataclass
class Request:
    """Back-compat request record (prompts may have different lengths)."""
    rid: int
    prompt: jnp.ndarray          # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)


class Engine:
    """Back-compat facade: paged continuous batching for attention families,
    dense equal-length loop for recurrent/encdec families."""

    def __init__(self, cfg, batch_slots: int, max_len: int, mesh=None,
                 backend: str | None = None):
        self.cfg = cfg
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.paged = cfg.family in SUPPORTED_FAMILIES
        if self.paged:
            # mesh= (("data","model") Mesh) routes to the sharded engine when
            # it spans >1 device; a 1x1 mesh is the plain engine
            self._eng = PagedEngine(cfg, n_slots=batch_slots, max_len=max_len,
                                    backend=backend, mesh=mesh)
        else:
            self.model = build(cfg)
            self.params = self.model.init(jax.random.PRNGKey(0))
            self.cache = self.model.init_cache(batch_slots, max_len)
            self._decode = jax.jit(self.model.decode_step)
            self._prefill = jax.jit(self.model.prefill)

    # ------------------------------------------------------------ paged
    def _run_paged(self, requests: list[Request], new_tokens: int) -> dict:
        t0 = time.time()
        serve_reqs = []
        for r in requests:
            sr = ServeRequest(prompt=np.asarray(r.prompt),
                              max_new=min(r.max_new, new_tokens))
            self._eng.scheduler.submit(sr)
            serve_reqs.append(sr)
        summary = self._eng.run()
        for r, sr in zip(requests, serve_reqs):
            r.out = list(sr.out)
        s = self._eng.stats
        return {"prefill_s": s["prefill_s"],
                "decode_s_per_token": s["decode_s"] / max(s["decode_ticks"], 1),
                "total_s": time.time() - t0,
                "page_util": summary["peak_page_util"],
                "outputs": [r.out for r in requests]}

    # ------------------------------------------------------------ dense
    def _run_dense(self, requests: list[Request], new_tokens: int) -> dict:
        """Equal-length dense loop (recurrent state is one row per slot, so
        variable-length admission needs per-slot state capture — tracked as
        an extension; the paged path above has no such restriction)."""
        lens = {int(np.asarray(r.prompt).shape[0]) for r in requests}
        if len(lens) != 1:
            raise NotImplementedError(
                f"family '{self.cfg.family}' serves equal-length batches only "
                f"(got prompt lengths {sorted(lens)})")
        if len(requests) != self.batch_slots:
            raise ValueError("dense fallback needs one request per slot")
        toks = jnp.stack([jnp.asarray(r.prompt) for r in requests])
        batch = {"tokens": toks, "labels": jnp.full_like(toks, -100)}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (len(requests), self.cfg.enc_seq, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        t0 = time.time()
        logits, self.cache = self._prefill(self.params, self.cache, batch)
        pos = int(toks.shape[1])
        nxt = jnp.argmax(logits[:, :self.cfg.vocab], axis=-1).astype(jnp.int32)
        for r, t in zip(requests, list(nxt)):
            r.out.append(int(t))
        prefill_s = time.time() - t0
        t1 = time.time()
        for _ in range(new_tokens - 1):
            logits, self.cache = self._decode(
                self.params, self.cache, nxt,
                jnp.full((len(requests),), pos, jnp.int32))
            pos += 1
            nxt = jnp.argmax(logits[:, :self.cfg.vocab], axis=-1).astype(jnp.int32)
            for r, t in zip(requests, list(nxt)):
                if len(r.out) < min(r.max_new, new_tokens):
                    r.out.append(int(t))
        decode_s = time.time() - t1
        return {"prefill_s": prefill_s,
                "decode_s_per_token": decode_s / max(new_tokens - 1, 1),
                "total_s": time.time() - t0,
                "outputs": [r.out for r in requests]}

    def run(self, requests: list[Request], new_tokens: int) -> dict:
        if self.paged:
            return self._run_paged(requests, new_tokens)
        return self._run_dense(requests, new_tokens)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--slots", "--batch", dest="slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64,
                    help="max prompt length; mixed traffic draws 1/4..1x of it")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=0,
                    help="total requests (default: 2x slots)")
    ap.add_argument("--backend", default=None,
                    help="paged-decode backend (repro.attention registry "
                         "name, e.g. paged_kernel | paged_gather)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="shard serving over a (data, model) mesh, e.g. 2x4 "
                         "(needs data*model devices; model must divide "
                         "n_kv_heads, data must divide --slots)")
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = None
    if args.mesh is not None:
        from repro.launch.mesh import make_mesh
        d, m = (int(x) for x in args.mesh.lower().split("x"))
        mesh = make_mesh((d, m), ("data", "model"))
    eng = Engine(cfg, args.slots, args.prompt_len + args.new_tokens + 8,
                 mesh=mesh, backend=args.backend)
    # dense fallback families decode one fixed batch: one request per slot
    n_req = (args.requests or 2 * args.slots) if eng.paged else args.slots
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n_req):
        plen = (args.prompt_len if not eng.paged
                else int(rng.integers(max(args.prompt_len // 4, 1),
                                      args.prompt_len + 1)))
        reqs.append(Request(i, jnp.asarray(
            rng.integers(0, cfg.vocab, size=(plen,)), jnp.int32),
            max_new=args.new_tokens))
    stats = eng.run(reqs, args.new_tokens)
    print(f"[serve] prefill {stats['prefill_s']*1e3:.1f}ms  "
          f"decode {stats['decode_s_per_token']*1e3:.1f}ms/token")
    if "page_util" in stats:
        print(f"[serve] peak page-pool utilization {stats['page_util']:.1%}")
    print(f"[serve] sample output: {stats['outputs'][0][:12]}")


if __name__ == "__main__":
    main()
