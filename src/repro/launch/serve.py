"""Batched serving driver: prefill + decode with continuous batch slots.

A minimal production-shaped server loop: fixed batch of decode slots; new
requests prefill into a free slot; every engine tick decodes one token for
all active slots (the NSA decode path touches only compressed + selected +
window KV, so a tick is O(N/stride) per slot, not O(N)).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.launch.mesh import make_mesh
from repro.models import build


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray          # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)


class Engine:
    def __init__(self, cfg, batch_slots: int, max_len: int, mesh=None):
        self.cfg = cfg
        self.model = build(cfg)
        self.params = self.model.init(jax.random.PRNGKey(0))
        self.cache = self.model.init_cache(batch_slots, max_len)
        self.slots: list[Request | None] = [None] * batch_slots
        self.pos = 0
        self.max_len = max_len
        self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(self.model.prefill)

    def add_batch(self, requests: list[Request]):
        """Prefill a full batch of same-length prompts (batched serving)."""
        assert len(requests) == len(self.slots)
        toks = jnp.stack([r.prompt for r in requests])
        batch = {"tokens": toks,
                 "labels": jnp.full_like(toks, -100)}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (len(requests), self.cfg.enc_seq, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        logits, self.cache = self._prefill(self.params, self.cache, batch)
        self.pos = toks.shape[1]
        nxt = jnp.argmax(logits[:, :self.cfg.vocab], axis=-1).astype(jnp.int32)
        for r, t in zip(requests, list(nxt)):
            r.out.append(int(t))
        self.slots = list(requests)
        return nxt

    def tick(self, tokens):
        """One decode step for every slot."""
        logits, self.cache = self._decode(self.params, self.cache, tokens,
                                          jnp.asarray(self.pos))
        self.pos += 1
        nxt = jnp.argmax(logits[:, :self.cfg.vocab], axis=-1).astype(jnp.int32)
        for r, t in zip(self.slots, list(nxt)):
            if r is not None and len(r.out) < r.max_new:
                r.out.append(int(t))
        return nxt

    def run(self, requests, new_tokens: int):
        t0 = time.time()
        tokens = self.add_batch(requests)
        prefill_s = time.time() - t0
        t1 = time.time()
        for _ in range(new_tokens - 1):
            tokens = self.tick(tokens)
        decode_s = time.time() - t1
        return {"prefill_s": prefill_s,
                "decode_s_per_token": decode_s / max(new_tokens - 1, 1),
                "outputs": [r.out for r in requests]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    eng = Engine(cfg, args.batch, args.prompt_len + args.new_tokens + 8)
    reqs = [Request(i, jax.random.randint(jax.random.PRNGKey(i),
                                          (args.prompt_len,), 0, cfg.vocab),
                    max_new=args.new_tokens)
            for i in range(args.batch)]
    stats = eng.run(reqs, args.new_tokens)
    print(f"[serve] prefill {stats['prefill_s']*1e3:.1f}ms  "
          f"decode {stats['decode_s_per_token']*1e3:.1f}ms/token")
    print(f"[serve] sample output: {stats['outputs'][0][:12]}")


if __name__ == "__main__":
    main()
