"""Post-optimization HLO analysis with while-loop trip-count correction.

``compiled.cost_analysis()`` counts a while-loop body ONCE, but a
scanned-layers model executes it n_layers times (and chunked attention / loss
loops execute S/chunk times) — without correction every roofline term is off
by up to L×.  This module parses ``compiled.as_text()`` into computations,
builds a per-computation symbol table (var -> shape), extracts each while
loop's trip count from its condition, and accumulates:

  * dot FLOPs            (2 · |out| · contraction)
  * memory bytes         (operands + result of top-level ops; fusions are
                          counted at their boundary only — post-fusion HLO
                          makes this a realistic traffic model)
  * collective bytes     (by kind; reduce-scatter scaled by group size)

through the call graph with multipliers.  Unknown trip counts multiply by 1
and set ``"trip_count_unknown"``.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_SHAPE_TOK = re.compile(r"^\(?(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^(?:\(?[\w\[\],\s]*\)?\{?[\d,]*\}?\s+)?([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_NAME_TOK = re.compile(r"%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

# ops that move no HBM data on their own
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "copy-start", "copy-done", "after-all", "partition-id",
             "replica-id", "custom-call", "bitcast-convert", "iota",
             "get-dimension-size", "opt-barrier"}


def _shape_bytes_of(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in re.findall(r"(\w+)\[([\d,]*)\]", type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.shapes: dict[str, str] = {}       # var -> full type string
        self.ops: list[dict] = []              # parsed op records


def parse(hlo: str):
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.strip()
        hdr = _COMP_HDR.match(line)
        if hdr and raw.rstrip().endswith("{") and " -> " in line:
            cur = Computation(hdr.group(2))
            comps[cur.name] = cur
            if hdr.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line == "}":
            cur = None
            continue
        d = _DEF_RE.match(line)
        if not d:
            continue
        var, rhs = d.group(1), d.group(2)
        # result type = leading type token(s) of rhs
        tm = re.match(r"^(\([^)]*\)|\w+\[[\d,]*\]\S*)", rhs)
        rtype = tm.group(1) if tm else ""
        cur.shapes[var] = rtype
        om = re.match(r"^(?:\([^)]*\)|\w+\[[\d,]*\]\S*)?\s*([\w\-]+)", rhs)
        opname = om.group(1) if om else ""
        # operand names: inside the first (...) after the op name
        args_m = re.search(re.escape(opname) + r"\(([^)]*)\)", rhs) if opname else None
        operands = []
        if args_m:
            operands = [n for n in _NAME_TOK.findall(args_m.group(1))
                        if n in cur.shapes or not n.isdigit()]
        cur.ops.append({"var": var, "op": opname, "rhs": rhs,
                        "operands": operands, "rtype": rtype})
    return comps, entry


def _trip_count(cond: Computation) -> int | None:
    best = None
    for op in cond.ops:
        for c in _CONST_RE.findall(op["rhs"]):
            v = int(c)
            best = v if best is None else max(best, v)
    return best


def _dot_flops(op, comp: Computation) -> float:
    out_elems = _shape_bytes_of(op["rtype"])
    # element count, not bytes:
    m = _SHAPE_TOK.match(op["rtype"])
    if not m:
        return 0.0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    k = 1
    cm = re.search(r"rhs_contracting_dims=\{([\d,]*)\}", op["rhs"])
    if cm and len(op["operands"]) >= 2:
        rhs_name = op["operands"][1]
        rt = comp.shapes.get(rhs_name, "")
        sm = _SHAPE_TOK.match(rt)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for ci in cm.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    del out_elems
    return 2.0 * n * k


def analyze(hlo: str) -> dict:
    """Returns {"flops", "bytes", "collectives": {kind: bytes, "total"},
    "trip_count_unknown"?} — all trip-count corrected, per device."""
    comps, entry = parse(hlo)
    unknown = [False]
    cache: dict[str, tuple] = {}

    def walk(name: str):
        if name in cache:
            return cache[name]
        comp = comps[name]
        flops = 0.0
        mem = 0.0
        coll: dict[str, float] = defaultdict(float)
        for op in comp.ops:
            kind = op["op"]
            base = kind.replace("-start", "")
            # --- collectives ---
            if base in _COLLECTIVES and not kind.endswith("-done"):
                nbytes = _shape_bytes_of(op["rtype"])
                if base == "reduce-scatter":
                    g = re.search(r"replica_groups=\{\{([\d,]+)\}", op["rhs"])
                    if g:
                        nbytes *= len(g.group(1).split(","))
                coll[base] += nbytes
                mem += _shape_bytes_of(op["rtype"])
                continue
            # --- while loops ---
            if kind == "while":
                body_m = re.search(r"body=%?([\w.\-]+)", op["rhs"])
                cond_m = re.search(r"condition=%?([\w.\-]+)", op["rhs"])
                mult = None
                if cond_m and cond_m.group(1) in comps:
                    mult = _trip_count(comps[cond_m.group(1)])
                if mult is None:
                    mult = 1
                    unknown[0] = True
                if body_m and body_m.group(1) in comps:
                    f, b, c = walk(body_m.group(1))
                    flops += f * mult
                    mem += b * mult
                    for k2, v in c.items():
                        coll[k2] += v * mult
                continue
            # --- calls / conditionals / fusions ---
            callees = []
            for pat in (r"to_apply=%?([\w.\-]+)",
                        r"(?:true_computation|false_computation)=%?([\w.\-]+)",
                        r"calls=%?([\w.\-]+)",
                        r"branch_computations=\{([^}]*)\}"):
                for m in re.finditer(pat, op["rhs"]):
                    callees += _NAME_TOK.findall(m.group(1))
            if kind == "fusion":
                # fusion: count dot flops inside, memory at the boundary
                fc = re.search(r"calls=%?([\w.\-]+)", op["rhs"])
                if fc and fc.group(1) in comps:
                    f, _, c = walk(fc.group(1))
                    flops += f
                    for k2, v in c.items():
                        coll[k2] += v
                mem += _shape_bytes_of(op["rtype"])
                for o in op["operands"]:
                    mem += _shape_bytes_of(comp.shapes.get(o, ""))
                continue
            for callee in callees:
                if callee in comps and callee != name:
                    f, b, c = walk(callee)
                    flops += f
                    mem += b
                    for k2, v in c.items():
                        coll[k2] += v
            # --- dots ---
            if kind in ("dot", "convolution"):
                flops += _dot_flops(op, comp)
            # --- memory ---
            if kind in ("dynamic-slice", "gather", "slice"):
                mem += 2 * _shape_bytes_of(op["rtype"])   # read slice + write
            elif kind in ("dynamic-update-slice", "scatter"):
                upd = (_shape_bytes_of(comp.shapes.get(op["operands"][1], ""))
                       if len(op["operands"]) > 1 else 0)
                mem += 2 * upd                            # read + write update
            elif kind not in _FREE_OPS and kind != "while":
                mem += _shape_bytes_of(op["rtype"])
                for o in op["operands"]:
                    mem += _shape_bytes_of(comp.shapes.get(o, ""))
        cache[name] = (flops, mem, dict(coll))
        return cache[name]

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {"total": 0.0}}
    flops, mem, coll = walk(entry)
    out_coll = dict(coll)
    out_coll["total"] = float(sum(coll.values()))
    rec = {"flops": flops, "bytes": mem, "collectives": out_coll}
    if unknown[0]:
        rec["trip_count_unknown"] = True
    return rec
