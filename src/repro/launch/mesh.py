"""Production mesh construction (function, not module-level constant, so
importing this module never touches jax device state)."""
from __future__ import annotations

import numpy as np

import jax
import jax.sharding
from jax.sharding import Mesh

# jax >= 0.5 gained explicit axis types; on older releases (container pins
# 0.4.37) Mesh takes no ``axis_types`` argument and all axes are "auto".
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _mesh(devices, axes):
    if _AXIS_TYPE is not None:
        return Mesh(devices, axes, axis_types=(_AXIS_TYPE.Auto,) * len(axes))
    return Mesh(devices, axes)


def mesh_context(mesh: Mesh):
    """Context manager activating ``mesh`` (jax.set_mesh on new jax, the
    Mesh context manager on 0.4.x)."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis carries
    only the gradient all-reduce (pure DP), matching the DCN hierarchy.
    Scaling to 1000+ nodes grows the pod axis.

    Uses the first prod(shape) devices so the 256-chip mesh can be built in a
    512-device dry-run process."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py "
            f"sets this automatically)")
    return _mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh for tests / elastic restarts."""
    n = int(np.prod(shape))
    return _mesh(np.asarray(jax.devices()[:n]).reshape(tuple(shape)),
                 tuple(axes))
