"""Production mesh construction (function, not module-level constant, so
importing this module never touches jax device state)."""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis carries
    only the gradient all-reduce (pure DP), matching the DCN hierarchy.
    Scaling to 1000+ nodes grows the pod axis.

    Uses the first prod(shape) devices so the 256-chip mesh can be built in a
    512-device dry-run process."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py "
            f"sets this automatically)")
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes,
                axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh for tests / elastic restarts."""
    n = int(np.prod(shape))
    return Mesh(np.asarray(jax.devices()[:n]).reshape(tuple(shape)),
                tuple(axes), axis_types=(AxisType.Auto,) * len(axes))
