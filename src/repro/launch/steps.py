"""Jittable train / serve step functions + their sharding assignments.

``make_train_step`` returns (step_fn, in_shardings, out_shardings) ready for
``jax.jit(...).lower(...)`` — used by both the real training driver and the
multi-pod dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import build
from repro.optim import AdamWConfig, apply_updates, cosine_with_warmup
from repro.parallel import partition


def make_train_state_specs(cfg, params_shape, mesh, opt_cfg: AdamWConfig):
    pspecs = partition.param_specs(params_shape, mesh)
    return {"params": pspecs,
            "opt": __import__("repro.optim", fromlist=["opt_state_specs"])
                   .opt_state_specs(pspecs, opt_cfg)}


def make_train_step(cfg, mesh, opt_cfg: AdamWConfig | None = None, *,
                    schedule=cosine_with_warmup, num_microbatches: int = 1):
    """Returns train_step: (state, batch) -> (state, metrics).

    ``num_microbatches`` > 1 enables gradient accumulation: the global batch
    is split along dim 0 and scanned, bounding activation memory to one
    microbatch while gradients accumulate in fp32 (sharded like params)."""
    model = build(cfg)
    opt_cfg = opt_cfg or AdamWConfig()

    def grads_and_loss(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        return loss, metrics, grads

    def train_step(state, batch):
        from repro.parallel.axes import shard as _shard

        params, opt = state["params"], state["opt"]
        m = num_microbatches
        if m > 1:
            mb = jax.tree.map(
                lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch)

            def body(carry, microbatch):
                gacc, lacc = carry
                microbatch = jax.tree.map(
                    lambda x: _shard(x, "batch", *([None] * (x.ndim - 1))),
                    microbatch)
                loss, metrics, grads = grads_and_loss(params, microbatch)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                return (gacc, lacc + loss), metrics

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), metrics = jax.lax.scan(body, (gzero, 0.0), mb)
            grads = jax.tree.map(lambda g: g / m, gsum)
            loss = lsum / m
            metrics = jax.tree.map(lambda x: x[-1], metrics)
        else:
            loss, metrics, grads = grads_and_loss(params, batch)

        lr_scale = schedule(opt["step"])
        new_params, new_opt, opt_metrics = apply_updates(
            params, grads, opt, opt_cfg, lr_scale=lr_scale)
        metrics = {**metrics, **opt_metrics, "loss": loss,
                   "lr_scale": lr_scale}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_serve_step(cfg, mesh):
    """Decode step: (params, cache, tokens, pos) -> (logits, cache)."""
    model = build(cfg)

    def serve_step(params, cache, tokens, pos):
        logits, cache = model.decode_step(params, cache, tokens, pos)
        return logits, cache

    return serve_step


def state_shardings(cfg, mesh, opt_cfg: AdamWConfig, batch_example):
    """NamedShardings for (state, batch) of the train step."""
    model = build(cfg)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = partition.param_specs(params_shape, mesh)
    from repro.optim import opt_state_specs

    state_specs = {"params": pspecs, "opt": opt_state_specs(pspecs, opt_cfg)}
    batch_sp = partition.batch_specs(batch_example, mesh)
    ns = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    return ns(state_specs), ns(batch_sp), params_shape
