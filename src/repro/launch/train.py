"""Fault-tolerant training driver.

Single-host entry point (on a real cluster each host runs this under
``jax.distributed.initialize``; the mesh spans all hosts).  Features:
auto-resume from the newest valid checkpoint, deterministic step-indexed
data (bit-identical restart), heartbeat, straggler monitor, graceful
preemption, async checkpointing, non-finite-gradient skipping (inside the
jitted step), optional gradient accumulation.

Example (CPU, ~100M model):
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
      --steps 50 --batch 8 --seq 512 --mesh 1x1 --reduced
"""
from __future__ import annotations

import argparse
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import telemetry
from repro.checkpoint import ckpt
from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_mesh, mesh_context
from repro.launch.steps import make_train_step
from repro.models import build
from repro.optim import AdamWConfig, init_opt_state
from repro.parallel import partition
from repro.runtime.fault_tolerance import (FTConfig, GracefulStop, Heartbeat,
                                           StragglerMonitor)


def train_loop(cfg, *, steps: int, batch: int, seq: int, mesh,
               ft: FTConfig | None = None, opt_cfg: AdamWConfig | None = None,
               num_microbatches: int = 1, log_every: int = 10,
               frames_stub: bool = False, quiet: bool = False):
    ft = ft or FTConfig()
    opt_cfg = opt_cfg or AdamWConfig()
    model = build(cfg)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch))

    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, P))

    with mesh_context(mesh):
        params = model.init(jax.random.PRNGKey(0))
        pspecs = partition.param_specs(params, mesh)
        from repro.optim import opt_state_specs
        state = {"params": params, "opt": init_opt_state(params, opt_cfg)}
        state_specs = {"params": pspecs,
                       "opt": opt_state_specs(pspecs, opt_cfg)}
        state = jax.device_put(state, ns(state_specs))

        # --- auto-resume ---
        restored, start_step = ckpt.restore_latest(
            ft.ckpt_dir, state, shardings=ns(state_specs))
        if restored is not None:
            state = restored
            if not quiet:
                print(f"[train] resumed from step {start_step}")
        start = int(start_step or 0)

        step_fn = jax.jit(
            make_train_step(cfg, mesh, opt_cfg,
                            num_microbatches=num_microbatches),
            in_shardings=(ns(state_specs), None),
            out_shardings=(ns(state_specs), None),
            donate_argnums=(0,))

        hb = Heartbeat(ft.heartbeat_path)
        mon = StragglerMonitor(ft.straggler_factor, ft.window)
        stopper = GracefulStop()
        writer = None
        losses = []

        for step in range(start, steps):
            t0 = time.time()
            batch_data = data.batch_at(step)
            if frames_stub:
                batch_data["frames"] = jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(7), step),
                    (batch, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
            if cfg.family == "vlm":
                batch_data["img_embeds"] = jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(8), step),
                    (batch, cfg.n_img_tokens, cfg.d_model),
                    jnp.dtype(cfg.dtype))
            with telemetry.span("train.step") as sp:
                state, metrics = step_fn(state, batch_data)
                sp.sync(metrics)  # device-synced ms, not dispatch latency
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            straggler = mon.record(dt)
            hb.beat(step, loss=loss, dt=dt)
            if not quiet and (step % log_every == 0 or straggler):
                flag = " STRAGGLER" if straggler else ""
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f}ms{flag}")
            if ft.ckpt_every and (step + 1) % ft.ckpt_every == 0:
                if writer is not None:
                    writer.join()
                writer = ckpt.save_async(ft.ckpt_dir, step + 1, state,
                                         keep=ft.keep)
            if stopper.stop:
                if not quiet:
                    print(f"[train] preemption at step {step}: checkpointing")
                ckpt.save(ft.ckpt_dir, step + 1, state, keep=ft.keep)
                break
        if writer is not None:
            writer.join()
    return state, losses


import jax.numpy as jnp  # noqa: E402  (used by frames stub above)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 2x4")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU scale)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model"))
    ft = FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    _, losses = train_loop(cfg, steps=args.steps, batch=args.batch,
                           seq=args.seq, mesh=mesh, ft=ft,
                           num_microbatches=args.microbatches,
                           frames_stub=cfg.family == "encdec")
    print(f"[train] done: first loss {losses[0]:.4f} last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
