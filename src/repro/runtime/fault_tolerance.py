"""Fault-tolerance runtime: heartbeat, auto-resume, straggler monitor,
non-finite-gradient step skipping, elastic restart.

At 1000+ nodes the relevant failure modes are: node loss (process dies →
restart from checkpoint), hangs (heartbeat goes stale → supervisor kills),
stragglers (slow steps → logged + alerting threshold), and numeric blowups
(inf/nan gradients → step skipped inside the jitted update, see
optim.adamw.apply_updates).  Everything here is host-side and composes with
the jitted train step.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import signal
import time


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 50
    keep: int = 3
    heartbeat_path: str = "heartbeat.json"
    straggler_factor: float = 2.0     # step > factor × median ⇒ straggler
    window: int = 50                  # steps in the timing window


class Heartbeat:
    """Liveness file a supervisor (or the elastic launcher) watches."""

    def __init__(self, path, process_index: int = 0):
        self.path = pathlib.Path(path)
        self.process_index = process_index

    def beat(self, step: int, **extra):
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(
            {"step": step, "t": time.time(), "pid": os.getpid(),
             "process_index": self.process_index, **extra}))
        tmp.rename(self.path)

    def stale(self, timeout_s: float) -> bool:
        try:
            rec = json.loads(self.path.read_text())
            return time.time() - rec["t"] > timeout_s
        except Exception:  # noqa: BLE001
            return True


class StragglerMonitor:
    """Rolling median step-time; flags outlier steps (the single-host analogue
    of per-worker step-time variance tracking)."""

    def __init__(self, factor: float = 2.0, window: int = 50):
        self.factor = factor
        self.window = window
        self.times: list[float] = []
        self.flagged = 0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        self.times = self.times[-self.window:]
        med = sorted(self.times)[len(self.times) // 2]
        is_straggler = len(self.times) >= 5 and dt > self.factor * med
        self.flagged += int(is_straggler)
        return is_straggler


class GracefulStop:
    """SIGTERM/SIGINT → finish the current step, checkpoint, exit cleanly
    (what a preemption notice should do on a real cluster)."""

    def __init__(self):
        self.stop = False
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, self._handler)
            except ValueError:  # not main thread (tests)
                pass

    def _handler(self, signum, frame):
        self.stop = True


def elastic_mesh_for(world: int):
    """Pick a (data, model) mesh for the devices that are actually alive —
    restores from a mesh-agnostic checkpoint continue on the new topology."""
    model = 1
    for cand in (16, 8, 4, 2, 1):
        if world % cand == 0:
            model = cand
            break
    return (world // model, model), ("data", "model")
