"""repro.runtime — fault tolerance: heartbeat, stragglers, elastic restart."""
from repro.runtime.fault_tolerance import (FTConfig, GracefulStop, Heartbeat,
                                           StragglerMonitor, elastic_mesh_for)
