"""Continuous-batching serving engine on the paged NSA KV-cache.

Replaces the old fixed-batch loop in ``launch/serve.py``: prompts of any
length are admitted as slots and pages free up, and every engine tick is ONE
fused dispatch (``transformer.lm_paged_mixed_step``) that advances each
prefilling slot by one bounded chunk AND decodes one token for every active
slot at its own absolute position (a (B,) position vector, not a shared
scalar).  Decode therefore never stalls behind a long co-admitted prompt's
chunk loop — vLLM-style continuous batching — and the per-tick prefill work
is bounded by the scheduler's token budget.  The decode sub-step runs the
Pallas paged-decode kernel (``kernels/paged_decode.py``) by default, which
folds the slot batch into the MXU M dimension and reads KV through the page
table at page granularity.

The NSA decode tick reads only the pages its branches touch — compressed
rows, the top-T selected pages and the sliding window — so a tick is
O(N/stride + T·B_K + W) per slot regardless of context depth.

``fused=False`` keeps the previous sequential engine (prefill the whole
admission batch to completion, then decode) — the A/B reference for the
fused tick's token-identical-outputs guarantee.

Latency accounting: ``first_token_t`` is stamped PER REQUEST, after that
request's first token has been materialized on host (the blocking argmax
sync is inside the stamp, and inside ``prefill_s``) — never one shared
pre-sync timestamp for a whole admission batch.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.models import build, transformer
from repro.serving.cache import PagedNSACache
from repro.serving.prefix import PrefixCache
from repro.serving.scheduler import Request, Scheduler

SUPPORTED_FAMILIES = ("lm", "moe")


class Engine:
    """Paged continuous-batching engine for decoder-only attention models.

    ``mesh=`` (a ``("data", "model")`` jax Mesh) routes construction to
    ``repro.serving.sharded.ShardedEngine`` when the mesh spans more than one
    device: KV-head-sharded page pools, slot-sharded engine replicas, one
    ``shard_map``ped dispatch per tick.  A 1x1 mesh is byte-identical to the
    plain single-device engine (this class).
    """

    def __new__(cls, *args, mesh=None, **kwargs):
        if cls is Engine and mesh is not None and mesh.devices.size > 1:
            from repro.serving.sharded import ShardedEngine
            return super().__new__(ShardedEngine)
        return super().__new__(cls)

    def __init__(self, cfg, n_slots: int = 4, max_len: int = 1024, *,
                 num_pages: int | None = None, prefill_chunk: int | None = None,
                 params=None, seed: int = 0, backend: str | None = None,
                 mesh=None,
                 admit_limit: int | None = None,
                 prefill_token_budget: int | None = None,
                 fused: bool = True,
                 retain_outputs: int | None = 1024,
                 prefix_cache: bool = False,
                 metrics: "telemetry.Registry | None" = None,
                 metrics_port: int | None = None):
        if cfg.family not in SUPPORTED_FAMILIES:
            raise NotImplementedError(
                f"paged serving supports families {SUPPORTED_FAMILIES}, got "
                f"'{cfg.family}' (ssm/hybrid/encdec state is not paged KV)")
        del mesh   # 1-device meshes are byte-identical to the plain engine
        if backend is not None:      # override cfg.nsa.policy.paged_backend
            cfg = dataclasses.replace(cfg, nsa=dataclasses.replace(
                cfg.nsa, policy=dataclasses.replace(
                    cfg.nsa.policy, paged_backend=backend)))
        self.cfg = cfg
        self.model = build(cfg)
        self.params = (params if params is not None
                       else self.model.init(jax.random.PRNGKey(seed)))
        self.cache = self._make_cache(cfg, n_slots, max_len,
                                      num_pages=num_pages)
        p = self.cache.page_size
        # chunk-rounded prompts must fit one slot's page budget, so the
        # chunk never exceeds the slot's addressable rows
        self.prefill_chunk = min(prefill_chunk or 4 * p,
                                 self.cache.max_pages * p)
        # radix prefix cache (opt-in): admission matches prompts against it,
        # matched blocks alias shared physical pages and skip prefill; the
        # trie holds its own page references, so with it enabled pool.used
        # stays > 0 after a drain until eviction/reset
        self._prefix = self._make_prefix() if prefix_cache else None
        self.cache.prefix = self._prefix
        self.scheduler = Scheduler(self.cache, self.prefill_chunk,
                                   retain_outputs=retain_outputs,
                                   prefix=self._prefix)
        self.scheduler.on_release = self._on_release
        self.n_slots = n_slots
        # caps one step's admission batch (everything admitted together is
        # prefilled together in sequential mode, so this bounds how many
        # short prompts a long co-admitted one can stall); None = fill all
        # free slots
        self.admit_limit = admit_limit
        # fused mode: cap on prefill chunk tokens processed per tick
        # (scheduler admission enforces it; None = no cap beyond slot count)
        self.prefill_token_budget = prefill_token_budget
        self.fused = fused
        # per-request streaming hooks: on_token(req, tok) fires after the
        # token is on host (and appended to req.out); on_finish(req) after
        # the slot is recycled.  Set by AsyncEngine or any caller.
        self.on_token = None
        self.on_finish = None
        self._pf_pos: dict[int, int] = {}    # slot -> next chunk offset

        self._build_dispatch(cfg)
        self._last_tokens = np.zeros((n_slots,), np.int32)
        # the engine's own always-on registry: ``summary()``/``stats`` are
        # views over its snapshot, so core accounting never depends on
        # whether *global* telemetry (JSONL sink, dispatch counters,
        # profiler annotations) is switched on.  Pass ``metrics=`` to share
        # a registry across engines.
        self.telemetry = (metrics if metrics is not None
                          else telemetry.Registry(enabled=True, name="engine"))
        self._tick_no = 0
        # optional Prometheus pull endpoint over THIS engine's registry
        # (port 0 picks a free one; see handle.port / handle.url)
        self.metrics_server = (
            telemetry.serve_metrics(metrics_port, registry=self.telemetry)
            if metrics_port is not None else None)

    # --------------------------------------------------- construction hooks
    # Overridden by ``serving.sharded.ShardedEngine``: sharded cache facade,
    # per-replica prefix router, shard_mapped dispatch.  The scheduler, tick
    # loop, and accounting above them are shared verbatim.
    def _make_cache(self, cfg, n_slots, max_len, *, num_pages):
        return PagedNSACache(cfg, n_slots, max_len, num_pages=num_pages)

    def _make_prefix(self):
        return PrefixCache(self.cache)

    def _build_dispatch(self, cfg) -> None:
        # cfg is closed over (static); cache buffers are donated per call
        self._decode = jax.jit(
            lambda params, data, toks, pos, tables:
                transformer.lm_paged_decode_step(params, data, toks, pos,
                                                 tables, cfg),
            donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda params, data, toks, t0, length, tables:
                transformer.lm_paged_prefill_chunks(params, data, toks, t0,
                                                    length, tables, cfg),
            donate_argnums=(1,))
        self._mixed = jax.jit(
            lambda params, data, pf_toks, pf_t0, pf_len, dec_toks, dec_pos,
            dec_active, tables:
                transformer.lm_paged_mixed_step(
                    params, data, pf_toks, pf_t0, pf_len, dec_toks, dec_pos,
                    dec_active, tables, cfg),
            donate_argnums=(1,))

    # ------------------------------------------------ telemetry shortcuts
    def _count(self, name: str, n: float = 1, **labels) -> None:
        self.telemetry.counter(name, **labels).inc(n)

    def _tick_accounting(self, kind: str, seconds: float) -> None:
        self._count("engine_ticks_total", kind=kind)
        self._count("engine_tick_seconds_total", seconds, kind=kind)

    @property
    def stats(self) -> dict:
        """Legacy stats-dict view, derived from the telemetry snapshot
        (same keys as the pre-telemetry ad-hoc dict)."""
        snap = self.telemetry.snapshot()
        cv, gs = telemetry.counter_value, telemetry.gauge_stats
        return {
            "decoded_tokens": int(cv(snap, "engine_decoded_tokens_total")),
            "decode_ticks": int(cv(snap, "engine_ticks_total", kind="decode")),
            "decode_s": cv(snap, "engine_tick_seconds_total", kind="decode"),
            "prefill_tokens": int(cv(snap, "engine_prefill_tokens_total")),
            "prefill_s": cv(snap, "engine_tick_seconds_total",
                            kind="prefill"),
            "mixed_ticks": int(cv(snap, "engine_ticks_total", kind="mixed")),
            "mixed_s": cv(snap, "engine_tick_seconds_total", kind="mixed"),
            "peak_page_util": gs(snap, "engine_page_util", pool="raw")["max"],
            "peak_cmp_page_util": gs(snap, "engine_page_util",
                                     pool="cmp")["max"],
        }

    # ------------------------------------------------------------- intake
    def submit(self, prompt, max_new: int = 16, eos_id: int | None = None
               ) -> Request:
        return self.scheduler.submit(
            Request(prompt=np.asarray(prompt), max_new=max_new, eos_id=eos_id))

    def _on_release(self, req: Request) -> None:
        """Slot recycled: drop stale per-slot decode state so the freed
        slot's ride-along decode rows are reproducible (token 0 on the dump
        page) and a later occupant never inherits the old last token."""
        self._last_tokens[req.slot] = 0
        self._pf_pos.pop(req.slot, None)
        self._count("engine_finished_requests_total")
        self.telemetry.event("request", rid=req.rid, prompt_len=req.prompt_len,
                             new_tokens=req.num_out, **req.timeline())
        if self.on_finish is not None:
            self.on_finish(req)

    def _emit(self, req: Request, tok: int) -> None:
        req.out.append(tok)
        self._last_tokens[req.slot] = tok
        if self.on_token is not None:
            self.on_token(req, tok)

    def _track_util(self) -> dict:
        """Per-tick samples: queue depth, slot occupancy, raw+compressed
        page-pool utilization (gauges track last/min/max, so the summary's
        peaks fall out of the snapshot)."""
        util = self.cache.utilization()
        self.telemetry.gauge("engine_page_util", pool="raw").set(util["raw"])
        self.telemetry.gauge("engine_page_util", pool="cmp").set(util["cmp"])
        self.telemetry.gauge("engine_queue_depth").set(self.scheduler.pending)
        self.telemetry.gauge("engine_active_slots").set(
            len(self.scheduler.active))
        if self._prefix is not None:
            self.telemetry.gauge("prefix_blocks_cached").set(
                self._prefix.blocks_cached)
        return util

    # ------------------------------------------------------- prefix cache
    def _count_prefix_hits(self, admitted: list[Request]) -> None:
        for r in admitted:
            if r.cached_tokens:
                self._count("prefix_cache_hit_total")
                self._count("prefix_cache_blocks_reused_total",
                            r.cached_tokens // self.cache.page_size)

    def _register_prefix(self, req: Request) -> None:
        """Index the request's fully-materialized prompt blocks (called once
        its prefill completed — later requests sharing the prefix alias
        these physical pages and skip the work)."""
        if self._prefix is not None:
            self._prefix.insert(req.prompt, req.slot)

    # ------------------------------------------------------------ prefill
    def _prefill_requests(self, reqs: list[Request]) -> None:
        """Sequential-mode prefill: stream ALL newly admitted prompts
        together through the fixed-shape batched chunk jit, one dispatch per
        chunk step for the whole admission batch (padded to ``n_slots`` rows
        so the jit never recompiles).  Slots whose (shorter) prompt is
        already fully written ride along inertly — their writes land on the
        dump page."""
        if not reqs:
            return
        t_start = time.time()
        c = self.prefill_chunk
        bsz = self.n_slots
        lens = [len(r.prompt) for r in reqs]
        # prefix-cached tokens are already materialized in shared pages:
        # each slot's chunk stream starts at its own absolute offset
        skip = [r.cached_tokens for r in reqs]
        rem = [n - s for n, s in zip(lens, skip)]      # >= 1 (match cap)
        chunks = [-(-n // c) for n in rem]
        max_chunks = max(chunks)
        toks = np.zeros((bsz, max_chunks * c), np.int32)
        length = np.zeros((bsz,), np.int32)
        base = np.zeros((bsz,), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :rem[i]] = r.prompt[skip[i]:]
            length[i] = lens[i]
            base[i] = skip[i]
        tables = self.cache.views([r.slot for r in reqs], batch_size=bsz)
        length_j = jnp.asarray(length)
        last_logits = [None] * len(reqs)
        for kc in range(max_chunks):
            start = kc * c
            with telemetry.span("engine.prefill_chunk",
                                registry=self.telemetry):
                logits, self.cache.data = self._prefill(
                    self.params, self.cache.data,
                    jnp.asarray(toks[:, start:start + c]),
                    jnp.asarray(base + start), length_j, tables)
            if kc == 0:                      # whole batch got its 1st chunk
                t_chunk = time.time()
                for r in reqs:
                    if r.first_chunk_t is None:
                        r.first_chunk_t = t_chunk
            for i in range(len(reqs)):
                if kc == chunks[i] - 1:          # chunk with the last token
                    last_logits[i] = logits[i, (lens[i] - 1) - skip[i] - start,
                                            :self.cfg.vocab]
        with telemetry.span("engine.host_sync", registry=self.telemetry):
            for i, r in enumerate(reqs):
                self.cache.lengths[r.slot] = lens[i]
                self._register_prefix(r)
                tok = int(jnp.argmax(last_logits[i]))   # blocking host sync
                self._emit(r, tok)
                r.first_token_t = time.time()    # per request, post-sync
                self._count("engine_prefill_tokens_total", rem[i])
        self._tick_accounting("prefill", time.time() - t_start)

    def _prefill_request(self, req: Request) -> None:
        """Single-request prefill (compat wrapper over the batched path)."""
        self._prefill_requests([req])

    # -------------------------------------------------------------- ticks
    def _finish_ready(self) -> list[Request]:
        done = []
        for req in self.scheduler.active:
            if (len(req.out) >= req.max_new
                    or (req.eos_id is not None and req.out
                        and req.out[-1] == req.eos_id)):
                self.scheduler.release(req)
                done.append(req)
        return done

    def _decode_tick(self) -> None:
        """One token for every active slot at its own position."""
        t0 = time.time()
        pos = jnp.asarray(self.cache.lengths, jnp.int32)
        with telemetry.span("engine.decode", registry=self.telemetry):
            logits, self.cache.data = self._decode(
                self.params, self.cache.data, jnp.asarray(self._last_tokens),
                pos, self.cache.views())
        with telemetry.span("engine.host_sync", registry=self.telemetry):
            nxt = np.asarray(jnp.argmax(logits[:, :self.cfg.vocab], axis=-1),
                             np.int32)
        for req in self.scheduler.active:
            s = req.slot
            self._emit(req, int(nxt[s]))
            self.cache.lengths[s] += 1
            self._count("engine_decoded_tokens_total")
        self._tick_accounting("decode", time.time() - t0)

    # --------------------------------------------------------- fused tick
    def _prefill_tokens_in_flight(self) -> int:
        """Chunk tokens the CURRENT prefilling slots will consume next tick
        (the scheduler's admission budget adds to this)."""
        total = 0
        for req in self.scheduler.active:
            t0 = self._pf_pos.get(req.slot)
            if t0 is not None:
                total += min(self.prefill_chunk, len(req.prompt) - t0)
        return total

    def _step_fused(self) -> dict:
        """ONE fused dispatch: a bounded prefill chunk for admitting slots +
        one decode token for active slots, co-scheduled."""
        with telemetry.span("engine.admit", registry=self.telemetry) as sp:
            admitted = self.scheduler.admit(
                self.admit_limit, token_budget=self.prefill_token_budget,
                tokens_in_flight=self._prefill_tokens_in_flight())
            sp.annotate(admitted=len(admitted))
        self._count("engine_admitted_requests_total", len(admitted))
        self._count_prefix_hits(admitted)
        for r in admitted:
            # prefill resumes past the prefix-cached tokens (0 on a miss)
            self._pf_pos[r.slot] = r.cached_tokens
        util = self._track_util()

        c, bsz = self.prefill_chunk, self.n_slots
        prefilling = [r for r in self.scheduler.active
                      if r.slot in self._pf_pos]
        decoding = [r for r in self.scheduler.active
                    if r.slot not in self._pf_pos]
        if not prefilling and not decoding:
            return {"admitted": admitted, "finished": [], "active": 0,
                    "pending": self.scheduler.pending, "page_util": util,
                    "prefill_chunk_tokens": 0}

        t_tick = time.time()
        chunk_tokens = 0
        if prefilling:
            pf_toks = np.zeros((bsz, c), np.int32)
            pf_t0 = np.zeros((bsz,), np.int32)
            pf_len = np.zeros((bsz,), np.int32)   # 0 rows are inert
            for r in prefilling:
                s, t0 = r.slot, self._pf_pos[r.slot]
                n = min(c, len(r.prompt) - t0)
                pf_toks[s, :n] = r.prompt[t0:t0 + n]
                pf_t0[s], pf_len[s] = t0, len(r.prompt)
                chunk_tokens += n
            dec_active = np.zeros((bsz,), bool)
            for r in decoding:
                dec_active[r.slot] = True
            # the fused dispatch IS the tick's prefill-chunk phase (decode
            # rides along in the same launch)
            with telemetry.span("engine.prefill_chunk",
                                registry=self.telemetry,
                                fused=bool(decoding)) as sp:
                sp.annotate(chunk_tokens=chunk_tokens)
                pf_logits, dec_logits, self.cache.data = self._mixed(
                    self.params, self.cache.data, jnp.asarray(pf_toks),
                    jnp.asarray(pf_t0), jnp.asarray(pf_len),
                    jnp.asarray(self._last_tokens),
                    jnp.asarray(self.cache.lengths, jnp.int32),
                    jnp.asarray(dec_active), self.cache.views())
            t_chunk = time.time()
            for r in prefilling:             # chunk dispatched for these
                if r.first_chunk_t is None:
                    r.first_chunk_t = t_chunk
        else:   # steady-state decode: skip the (B, C) prefill sub-step
            with telemetry.span("engine.decode", registry=self.telemetry):
                dec_logits, self.cache.data = self._decode(
                    self.params, self.cache.data,
                    jnp.asarray(self._last_tokens),
                    jnp.asarray(self.cache.lengths, jnp.int32),
                    self.cache.views())
            pf_logits = None

        with telemetry.span("engine.host_sync", registry=self.telemetry):
            # prefill progress: advance each slot one chunk; a slot whose
            # chunk covered its last prompt token materializes its FIRST
            # token now
            for r in prefilling:
                s, t0 = r.slot, self._pf_pos[r.slot]
                self._count("engine_prefill_tokens_total",
                            min(c, len(r.prompt) - t0))
                if t0 + c >= len(r.prompt):
                    tok = int(jnp.argmax(            # blocking host sync
                        pf_logits[s, (len(r.prompt) - 1) - t0,
                                  :self.cfg.vocab]))
                    del self._pf_pos[s]
                    self.cache.lengths[s] = len(r.prompt)
                    self._register_prefix(r)
                    self._emit(r, tok)
                    r.first_token_t = time.time()    # per request, post-sync
                else:
                    self._pf_pos[s] = t0 + c
            if decoding:
                nxt = np.asarray(jnp.argmax(dec_logits[:, :self.cfg.vocab],
                                            axis=-1), np.int32)
                for r in decoding:
                    s = r.slot
                    self._emit(r, int(nxt[s]))
                    self.cache.lengths[s] += 1
                    self._count("engine_decoded_tokens_total")

        dt = time.time() - t_tick
        kind = ("mixed" if prefilling and decoding
                else "decode" if decoding else "prefill")
        self._tick_accounting(kind, dt)
        finished = self._finish_ready()
        return {"admitted": admitted, "finished": finished,
                "active": len(self.scheduler.active),
                "pending": self.scheduler.pending, "page_util": util,
                "prefill_chunk_tokens": chunk_tokens}

    def _step_sequential(self) -> dict:
        """Legacy two-phase iteration: admit + full prefill, then decode."""
        with telemetry.span("engine.admit", registry=self.telemetry) as sp:
            admitted = self.scheduler.admit(self.admit_limit)
            sp.annotate(admitted=len(admitted))
        self._count("engine_admitted_requests_total", len(admitted))
        self._count_prefix_hits(admitted)
        self._prefill_requests(admitted)
        util = self._track_util()
        finished = self._finish_ready()       # requests done at prefill
        if self.scheduler.active:
            self._decode_tick()
            finished += self._finish_ready()
        return {"admitted": admitted, "finished": finished,
                "active": len(self.scheduler.active),
                "pending": self.scheduler.pending, "page_util": util}

    def step(self) -> dict:
        """One engine iteration (fused mixed tick unless ``fused=False``)."""
        self._tick_no += 1
        with telemetry.span("engine.tick", registry=self.telemetry) as sp:
            out = (self._step_fused() if self.fused
                   else self._step_sequential())
            sp.annotate(tick=self._tick_no)
        self.telemetry.event(
            "tick", tick=self._tick_no,
            queue_depth=self.scheduler.pending,
            active_slots=out["active"],
            admitted=len(out["admitted"]), finished=len(out["finished"]),
            page_util_raw=out["page_util"]["raw"],
            page_util_cmp=out["page_util"]["cmp"],
            prefill_chunk_tokens=out.get("prefill_chunk_tokens", 0))
        return out

    def run(self, requests=None, *, max_steps: int | None = None) -> dict:
        """Drive until all traffic (queued + active) has drained."""
        if requests:
            for r in requests:
                self.scheduler.submit(r)
        steps = 0
        while not self.scheduler.idle():
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.summary()

    def summary(self) -> dict:
        """Serving summary, derived from the telemetry snapshot (the keys
        predate the telemetry subsystem and are kept byte-compatible —
        ``serve_bench``/``check_regression`` gate on them)."""
        snap = self.telemetry.snapshot()
        cv, gs = telemetry.counter_value, telemetry.gauge_stats
        tick_s = lambda kind: cv(snap, "engine_tick_seconds_total", kind=kind)
        ticks = lambda kind: cv(snap, "engine_ticks_total", kind=kind)
        decoded = int(cv(snap, "engine_decoded_tokens_total"))
        prefill_tokens = int(cv(snap, "engine_prefill_tokens_total"))
        # overlapped accounting: during a mixed tick BOTH streams progress,
        # so each stream's throughput window includes mixed time
        decode_window = tick_s("decode") + tick_s("mixed")
        prefill_window = tick_s("prefill") + tick_s("mixed")
        decode_ticks = ticks("decode") + ticks("mixed")
        admitted = cv(snap, "engine_admitted_requests_total")
        return {
            "requests_finished": len(self.scheduler.finished),
            "decoded_tokens": decoded,
            "decode_tokens_per_s": decoded / max(decode_window, 1e-9),
            "prefill_tokens_per_s":
                prefill_tokens / max(prefill_window, 1e-9),
            "decode_ms_per_tick": 1e3 * decode_window / max(decode_ticks, 1),
            "mixed_ticks": int(ticks("mixed")),
            "peak_page_util": gs(snap, "engine_page_util", pool="raw")["max"],
            "peak_cmp_page_util": gs(snap, "engine_page_util",
                                     pool="cmp")["max"],
            # prefix cache (0 / absent-series defaults when disabled)
            "prefix_hit_rate":
                cv(snap, "prefix_cache_hit_total") / max(admitted, 1),
            "prefix_blocks_reused":
                int(cv(snap, "prefix_cache_blocks_reused_total")),
            "prefix_blocks_cached":
                int(gs(snap, "prefix_blocks_cached")["last"]),
            # bounded retention: requests evicted past ``retain_outputs``
            # keep counts + timeline but no token lists (see Scheduler)
            "outputs": {r.rid: list(r.out) for r in self.scheduler.finished
                        if not r.out_evicted},
        }

    def timelines(self) -> dict:
        """{rid: per-request timeline} for every finished request (retained
        through output eviction — stamps are five floats)."""
        return {r.rid: r.timeline() for r in self.scheduler.finished}
