"""Continuous-batching serving engine on the paged NSA KV-cache.

Replaces the old fixed-batch loop in ``launch/serve.py``: prompts of any
length are admitted as slots and pages free up, prefill streams each prompt
through a fixed-shape chunked jit, and every engine tick decodes one token
for all active slots at their own absolute positions (a (B,) position
vector, not a shared scalar).

The NSA decode tick reads only the pages its branches touch — compressed
rows, the top-T selected pages and the sliding window — so a tick is
O(N/stride + T·B_K + W) per slot regardless of context depth.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import build, transformer
from repro.serving.cache import PagedNSACache
from repro.serving.scheduler import Request, Scheduler

SUPPORTED_FAMILIES = ("lm", "moe")


class Engine:
    """Paged continuous-batching engine for decoder-only attention models."""

    def __init__(self, cfg, n_slots: int = 4, max_len: int = 1024, *,
                 num_pages: int | None = None, prefill_chunk: int | None = None,
                 params=None, seed: int = 0):
        if cfg.family not in SUPPORTED_FAMILIES:
            raise NotImplementedError(
                f"paged serving supports families {SUPPORTED_FAMILIES}, got "
                f"'{cfg.family}' (ssm/hybrid/encdec state is not paged KV)")
        self.cfg = cfg
        self.model = build(cfg)
        self.params = (params if params is not None
                       else self.model.init(jax.random.PRNGKey(seed)))
        self.cache = PagedNSACache(cfg, n_slots, max_len, num_pages=num_pages)
        p = self.cache.page_size
        # chunk-rounded prompts must fit one slot's page budget, so the
        # chunk never exceeds the slot's addressable rows
        self.prefill_chunk = min(prefill_chunk or 4 * p,
                                 self.cache.max_pages * p)
        self.scheduler = Scheduler(self.cache, self.prefill_chunk)
        self.n_slots = n_slots

        # cfg is closed over (static); cache buffers are donated per call
        self._decode = jax.jit(
            lambda params, data, toks, pos, tables:
                transformer.lm_paged_decode_step(params, data, toks, pos,
                                                 tables, cfg),
            donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda params, data, toks, t0, length, tables:
                transformer.lm_paged_prefill_chunk(params, data, toks, t0,
                                                   length, tables, cfg),
            donate_argnums=(1,))
        self._last_tokens = np.zeros((n_slots,), np.int32)
        self.stats = {"decoded_tokens": 0, "decode_ticks": 0, "decode_s": 0.0,
                      "prefill_tokens": 0, "prefill_s": 0.0,
                      "peak_page_util": 0.0}

    # ------------------------------------------------------------- intake
    def submit(self, prompt, max_new: int = 16, eos_id: int | None = None
               ) -> Request:
        return self.scheduler.submit(
            Request(prompt=np.asarray(prompt), max_new=max_new, eos_id=eos_id))

    # ------------------------------------------------------------ prefill
    def _prefill_request(self, req: Request) -> None:
        """Stream the prompt through the fixed-shape chunk jit into pages."""
        t0 = time.time()
        c = self.prefill_chunk
        length = len(req.prompt)
        padded = -(-length // c) * c
        toks = np.zeros((padded,), np.int32)
        toks[:length] = req.prompt
        tables = self.cache.slot_tables(req.slot)
        logits = None
        for start in range(0, padded, c):
            logits, self.cache.data = self._prefill(
                self.params, self.cache.data, jnp.asarray(toks[start:start + c]),
                jnp.int32(start), jnp.int32(length), tables)
        self.cache.lengths[req.slot] = length
        last = logits[(length - 1) - (padded - c), :self.cfg.vocab]
        tok = int(jnp.argmax(last))
        req.out.append(tok)
        req.first_token_t = time.time()
        self._last_tokens[req.slot] = tok
        self.stats["prefill_tokens"] += length
        self.stats["prefill_s"] += time.time() - t0

    # -------------------------------------------------------------- ticks
    def _finish_ready(self) -> list[Request]:
        done = []
        for req in self.scheduler.active:
            if (len(req.out) >= req.max_new
                    or (req.eos_id is not None and req.out
                        and req.out[-1] == req.eos_id)):
                self.scheduler.release(req)
                done.append(req)
        return done

    def _decode_tick(self) -> None:
        """One token for every active slot at its own position."""
        t0 = time.time()
        pos = jnp.asarray(self.cache.lengths, jnp.int32)
        logits, self.cache.data = self._decode(
            self.params, self.cache.data, jnp.asarray(self._last_tokens), pos,
            self.cache.device_tables())
        nxt = np.asarray(jnp.argmax(logits[:, :self.cfg.vocab], axis=-1),
                         np.int32)
        for req in self.scheduler.active:
            s = req.slot
            req.out.append(int(nxt[s]))
            self._last_tokens[s] = nxt[s]
            self.cache.lengths[s] += 1
            self.stats["decoded_tokens"] += 1
        self.stats["decode_ticks"] += 1
        self.stats["decode_s"] += time.time() - t0

    def step(self) -> dict:
        """One engine iteration: admit + prefill, decode, recycle slots."""
        admitted = self.scheduler.admit()
        for req in admitted:
            self._prefill_request(req)
        util = self.cache.utilization()
        self.stats["peak_page_util"] = max(self.stats["peak_page_util"],
                                           util["raw"])
        finished = self._finish_ready()       # requests done at prefill
        if self.scheduler.active:
            self._decode_tick()
            finished += self._finish_ready()
        return {"admitted": admitted, "finished": finished,
                "active": len(self.scheduler.active),
                "pending": self.scheduler.pending, "page_util": util}

    def run(self, requests=None, *, max_steps: int | None = None) -> dict:
        """Drive until all traffic (queued + active) has drained."""
        if requests:
            for r in requests:
                self.scheduler.submit(r)
        steps = 0
        while not self.scheduler.idle():
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.summary()

    def summary(self) -> dict:
        s = self.stats
        return {
            "requests_finished": len(self.scheduler.finished),
            "decoded_tokens": s["decoded_tokens"],
            "decode_tokens_per_s": s["decoded_tokens"] / max(s["decode_s"], 1e-9),
            "prefill_tokens_per_s": s["prefill_tokens"] / max(s["prefill_s"], 1e-9),
            "decode_ms_per_tick": 1e3 * s["decode_s"] / max(s["decode_ticks"], 1),
            "peak_page_util": s["peak_page_util"],
            "outputs": {r.rid: list(r.out) for r in self.scheduler.finished},
        }
