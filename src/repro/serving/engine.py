"""Continuous-batching serving engine on the paged NSA KV-cache.

Replaces the old fixed-batch loop in ``launch/serve.py``: prompts of any
length are admitted as slots and pages free up, prefill streams ALL newly
admitted prompts together through one fixed-shape batched chunk jit, and
every engine tick decodes one token for all active slots at their own
absolute positions (a (B,) position vector, not a shared scalar) in ONE
batched dispatch — the Pallas paged-decode kernel
(``kernels/paged_decode.py``) by default, which folds the slot batch into
the MXU M dimension and reads KV through the page table at page granularity.

The NSA decode tick reads only the pages its branches touch — compressed
rows, the top-T selected pages and the sliding window — so a tick is
O(N/stride + T·B_K + W) per slot regardless of context depth.
"""
from __future__ import annotations

import dataclasses
import time
import warnings

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import build, transformer
from repro.serving.cache import PagedNSACache
from repro.serving.scheduler import Request, Scheduler

SUPPORTED_FAMILIES = ("lm", "moe")


class Engine:
    """Paged continuous-batching engine for decoder-only attention models."""

    def __init__(self, cfg, n_slots: int = 4, max_len: int = 1024, *,
                 num_pages: int | None = None, prefill_chunk: int | None = None,
                 params=None, seed: int = 0, backend: str | None = None,
                 use_kernel: bool | None = None,
                 admit_limit: int | None = None):
        if cfg.family not in SUPPORTED_FAMILIES:
            raise NotImplementedError(
                f"paged serving supports families {SUPPORTED_FAMILIES}, got "
                f"'{cfg.family}' (ssm/hybrid/encdec state is not paged KV)")
        if use_kernel is not None:   # deprecated spelling of backend=
            if backend is not None:
                raise ValueError("pass either backend= or the deprecated "
                                 "use_kernel flag, not both")
            warnings.warn(
                "the use_kernel flag of Engine is deprecated; pass "
                "backend='paged_kernel'|'paged_gather'", DeprecationWarning,
                stacklevel=2)
            backend = "paged_kernel" if use_kernel else "paged_gather"
        if backend is not None:      # override cfg.nsa.policy.paged_backend
            cfg = dataclasses.replace(cfg, nsa=dataclasses.replace(
                cfg.nsa, policy=dataclasses.replace(
                    cfg.nsa.policy, paged_backend=backend)))
        self.cfg = cfg
        self.model = build(cfg)
        self.params = (params if params is not None
                       else self.model.init(jax.random.PRNGKey(seed)))
        self.cache = PagedNSACache(cfg, n_slots, max_len, num_pages=num_pages)
        p = self.cache.page_size
        # chunk-rounded prompts must fit one slot's page budget, so the
        # chunk never exceeds the slot's addressable rows
        self.prefill_chunk = min(prefill_chunk or 4 * p,
                                 self.cache.max_pages * p)
        self.scheduler = Scheduler(self.cache, self.prefill_chunk)
        self.n_slots = n_slots
        # caps one step's admission batch (everything admitted together is
        # prefilled together, so this bounds how many short prompts a long
        # co-admitted one can stall); None = fill all free slots
        self.admit_limit = admit_limit

        # cfg is closed over (static); cache buffers are donated per call
        self._decode = jax.jit(
            lambda params, data, toks, pos, tables:
                transformer.lm_paged_decode_step(params, data, toks, pos,
                                                 tables, cfg),
            donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda params, data, toks, t0, length, tables:
                transformer.lm_paged_prefill_chunks(params, data, toks, t0,
                                                    length, tables, cfg),
            donate_argnums=(1,))
        self._last_tokens = np.zeros((n_slots,), np.int32)
        self.stats = {"decoded_tokens": 0, "decode_ticks": 0, "decode_s": 0.0,
                      "prefill_tokens": 0, "prefill_s": 0.0,
                      "peak_page_util": 0.0}

    # ------------------------------------------------------------- intake
    def submit(self, prompt, max_new: int = 16, eos_id: int | None = None
               ) -> Request:
        return self.scheduler.submit(
            Request(prompt=np.asarray(prompt), max_new=max_new, eos_id=eos_id))

    # ------------------------------------------------------------ prefill
    def _prefill_requests(self, reqs: list[Request]) -> None:
        """Stream ALL newly admitted prompts together through the fixed-shape
        batched chunk jit: one dispatch per chunk step for the whole
        admission batch (padded to ``n_slots`` rows so the jit never
        recompiles).  Slots whose (shorter) prompt is already fully written
        ride along inertly — their writes land on the dump page."""
        if not reqs:
            return
        t_start = time.time()
        c = self.prefill_chunk
        bsz = self.n_slots
        lens = [len(r.prompt) for r in reqs]
        padded = [-(-n // c) * c for n in lens]
        max_chunks = max(p // c for p in padded)
        toks = np.zeros((bsz, max_chunks * c), np.int32)
        length = np.zeros((bsz,), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :lens[i]] = r.prompt
            length[i] = lens[i]
        tables = self.cache.slot_tables_batch([r.slot for r in reqs],
                                              batch_size=bsz)
        length_j = jnp.asarray(length)
        last_logits = [None] * len(reqs)
        for kc in range(max_chunks):
            start = kc * c
            logits, self.cache.data = self._prefill(
                self.params, self.cache.data,
                jnp.asarray(toks[:, start:start + c]),
                jnp.full((bsz,), start, jnp.int32), length_j, tables)
            for i in range(len(reqs)):
                if kc == padded[i] // c - 1:     # chunk with the last token
                    last_logits[i] = logits[i, (lens[i] - 1) - start,
                                            :self.cfg.vocab]
        t_first = time.time()
        for i, r in enumerate(reqs):
            self.cache.lengths[r.slot] = lens[i]
            tok = int(jnp.argmax(last_logits[i]))
            r.out.append(tok)
            r.first_token_t = t_first
            self._last_tokens[r.slot] = tok
            self.stats["prefill_tokens"] += lens[i]
        self.stats["prefill_s"] += time.time() - t_start

    def _prefill_request(self, req: Request) -> None:
        """Single-request prefill (compat wrapper over the batched path)."""
        self._prefill_requests([req])

    # -------------------------------------------------------------- ticks
    def _finish_ready(self) -> list[Request]:
        done = []
        for req in self.scheduler.active:
            if (len(req.out) >= req.max_new
                    or (req.eos_id is not None and req.out
                        and req.out[-1] == req.eos_id)):
                self.scheduler.release(req)
                done.append(req)
        return done

    def _decode_tick(self) -> None:
        """One token for every active slot at its own position."""
        t0 = time.time()
        pos = jnp.asarray(self.cache.lengths, jnp.int32)
        logits, self.cache.data = self._decode(
            self.params, self.cache.data, jnp.asarray(self._last_tokens), pos,
            self.cache.device_tables())
        nxt = np.asarray(jnp.argmax(logits[:, :self.cfg.vocab], axis=-1),
                         np.int32)
        for req in self.scheduler.active:
            s = req.slot
            req.out.append(int(nxt[s]))
            self._last_tokens[s] = nxt[s]
            self.cache.lengths[s] += 1
            self.stats["decoded_tokens"] += 1
        self.stats["decode_ticks"] += 1
        self.stats["decode_s"] += time.time() - t0

    def step(self) -> dict:
        """One engine iteration: admit + prefill, decode, recycle slots."""
        admitted = self.scheduler.admit(self.admit_limit)
        self._prefill_requests(admitted)
        util = self.cache.utilization()
        self.stats["peak_page_util"] = max(self.stats["peak_page_util"],
                                           util["raw"])
        finished = self._finish_ready()       # requests done at prefill
        if self.scheduler.active:
            self._decode_tick()
            finished += self._finish_ready()
        return {"admitted": admitted, "finished": finished,
                "active": len(self.scheduler.active),
                "pending": self.scheduler.pending, "page_util": util}

    def run(self, requests=None, *, max_steps: int | None = None) -> dict:
        """Drive until all traffic (queued + active) has drained."""
        if requests:
            for r in requests:
                self.scheduler.submit(r)
        steps = 0
        while not self.scheduler.idle():
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.summary()

    def summary(self) -> dict:
        s = self.stats
        return {
            "requests_finished": len(self.scheduler.finished),
            "decoded_tokens": s["decoded_tokens"],
            "decode_tokens_per_s": s["decoded_tokens"] / max(s["decode_s"], 1e-9),
            "prefill_tokens_per_s": s["prefill_tokens"] / max(s["prefill_s"], 1e-9),
            "decode_ms_per_tick": 1e3 * s["decode_s"] / max(s["decode_ticks"], 1),
            "peak_page_util": s["peak_page_util"],
            "outputs": {r.rid: list(r.out) for r in self.scheduler.finished},
        }
