"""PagedNSACache: paged raw-KV + compressed-token storage for all layers.

Device state is a pytree of per-layer page pools (stacked over layers so the
transformer's ``lax.scan`` carries it like the dense cache), plus two shared
page tables (raw and compressed) — one allocation serves every layer, as in
MaxText's page_manager / vLLM.

Two pools because the two token kinds grow at different rates: raw pages at
1 row/token, compressed pages at 1 row per ``cmp_stride`` tokens.  Page size
is ``nsa.block_size`` for both, so the NSA selected branch addresses physical
pages directly.

Allocation is a single two-pool transaction over :class:`PageLease` handles
(both pools commit or neither does), and pages are ref-counted: a slot
admitted against a cached prefix aliases the trie's physical pages for its
leading table entries (see ``repro.serving.prefix``), copies the partially
filled boundary compressed page (copy-on-write — partial pages are private
by invariant), and allocates only the private remainder.  The device tables
carry per-slot write floors so no write can land below the shared prefix.

``views()`` is the one read accessor: device page tables for all slots, one
slot, or a padded slot batch, optionally with dense gathered K/V for a
layer.  The five pre-redesign spellings (``device_tables`` /
``slot_tables`` / ``slot_tables_batch`` / ``gather_view`` /
``gather_views``) remain as one-release deprecation shims.
"""
from __future__ import annotations

import warnings

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.core.paging import gather_rows
from repro.serving.pages import PagePool, PageTable, tables_array


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class PagedNSACache:
    """Paged decode cache for ``n_slots`` concurrent sequences.

    ``data`` is the device pytree handed to the jitted model functions
    (ownership transfers in/out each step so buffers can be donated).
    """

    def __init__(self, cfg, n_slots: int, max_len: int, *,
                 num_pages: int | None = None, alloc_data: bool = True):
        if cfg.family in ("ssm", "hybrid", "encdec"):
            raise NotImplementedError(
                f"paged KV serving needs an attention cache; family "
                f"'{cfg.family}' has recurrent/cross-attn state")
        self.cfg = cfg
        self.page_size = cfg.nsa.block_size
        self.n_slots = n_slots
        self.max_len = max_len
        self.max_pages = _ceil_div(max_len, self.page_size)
        # compressed tokens per slot at full depth, padded to whole pages
        self.max_cmp_tokens = cfg.nsa.num_cmp_blocks(max_len)
        self.max_cmp_pages = _ceil_div(self.max_cmp_tokens, self.page_size)
        # +1 everywhere: page 0 is the reserved dump page
        self.num_pages = (num_pages if num_pages is not None
                          else n_slots * self.max_pages + 1)
        self.num_cmp_pages = n_slots * self.max_cmp_pages + 1

        self.pool = PagePool(self.num_pages, self.page_size)
        self.cmp_pool = PagePool(self.num_cmp_pages, self.page_size)
        self.tables = [PageTable(self.max_pages) for _ in range(n_slots)]
        self.cmp_tables = [PageTable(self.max_cmp_pages) for _ in range(n_slots)]
        self.lengths = np.zeros((n_slots,), np.int64)   # tokens written
        # radix prefix cache (repro.serving.prefix.PrefixCache); when set,
        # alloc_slot accepts prefix matches and evicts LRU cached prefixes
        # under pool pressure
        self.prefix = None

        # ``alloc_data=False``: bookkeeping-only cache (page pools, tables,
        # lengths) with no device pytree — the sharded engine's per-replica
        # caches share one global sharded pytree owned by the facade instead
        self.data = (transformer.init_lm_paged_cache(
            cfg, self.num_pages, self.num_cmp_pages) if alloc_data else None)
        self._tables_dirty = True
        self._dev_tables = None

    # ------------------------------------------------------------- alloc
    def pages_needed(self, capacity_tokens: int) -> tuple[int, int]:
        """(raw, cmp) page count to hold ``capacity_tokens`` for one slot."""
        raw = _ceil_div(capacity_tokens, self.page_size)
        cmp_tokens = self.cfg.nsa.num_cmp_blocks(capacity_tokens)
        return raw, _ceil_div(cmp_tokens, self.page_size)

    def can_admit(self, capacity_tokens: int, prefix=None) -> bool:
        raw, cmp = self.pages_needed(capacity_tokens)
        if prefix is not None:
            raw -= len(prefix.raw_pages)
            cmp -= len(prefix.cmp_pages)
        return (raw <= self.max_pages and cmp <= self.max_cmp_pages
                and self.pool.can_alloc(raw) and self.cmp_pool.can_alloc(cmp))

    def alloc_slot(self, slot: int, capacity_tokens: int, *,
                   prefix=None) -> bool:
        """Reserve the slot's full worst-case page budget up front (simple
        admission control: an admitted request can never OOM mid-flight).

        One two-pool transaction: raw and compressed leases both commit or
        neither does.  ``prefix`` (a pinned ``PrefixMatch``) aliases the
        matched pages into the leading table entries instead of allocating
        them, copies the boundary compressed page (copy-on-write), and is
        CONSUMED either way — on failure its references are cancelled here.
        """
        raw_n, cmp_n = self.pages_needed(capacity_tokens)
        if raw_n > self.max_pages or cmp_n > self.max_cmp_pages:
            if prefix is not None:
                prefix.cancel()
            raise ValueError(
                f"request needs {raw_n} pages > slot capacity {self.max_pages} "
                f"(max_len={self.max_len})")
        shared_raw = prefix.raw_pages if prefix is not None else []
        shared_cmp = prefix.cmp_pages if prefix is not None else []
        raw_need = raw_n - len(shared_raw)
        cmp_need = cmp_n - len(shared_cmp)
        # under pressure, reclaim LRU cached prefixes before giving up (the
        # matched chain is ref-pinned, so evicting it only drops trie refs)
        if self.prefix is not None and not (
                self.pool.can_alloc(raw_need)
                and self.cmp_pool.can_alloc(cmp_need)):
            self.prefix.evict_for(raw_need, cmp_need)
        raw_lease = self.pool.try_alloc(raw_need)
        if raw_lease is None:
            if prefix is not None:
                prefix.cancel()
            return False
        cmp_lease = self.cmp_pool.try_alloc(cmp_need)
        if cmp_lease is None:
            raw_lease.release()
            if prefix is not None:
                prefix.cancel()
            return False
        raw_priv, cmp_priv = raw_lease.take(), cmp_lease.take()
        if prefix is not None and prefix.cmp_boundary is not None:
            # copy-on-write: the partially-filled trailing compressed page is
            # always private — this slot's prefill keeps appending rows to it
            self._copy_cmp_page(prefix.cmp_boundary, cmp_priv[0])
            self.cmp_pool.release([prefix.cmp_boundary])
        if prefix is not None:
            prefix.consume()    # raw/cmp full refs now owned by the tables
        self.tables[slot].assign(shared_raw + raw_priv,
                                 shared=len(shared_raw))
        self.cmp_tables[slot].assign(shared_cmp + cmp_priv,
                                     shared=len(shared_cmp))
        self.lengths[slot] = 0
        self._tables_dirty = True
        return True

    def free_slot(self, slot: int) -> None:
        """Drop the slot's reference on every page it mapped; pages shared
        with the prefix cache (or other slots) stay allocated."""
        self.pool.release(self.tables[slot].clear())
        self.cmp_pool.release(self.cmp_tables[slot].clear())
        self.lengths[slot] = 0
        self._tables_dirty = True

    def reset(self) -> None:
        for s in range(self.n_slots):
            self.tables[s].clear()
            self.cmp_tables[s].clear()
        if self.prefix is not None:
            self.prefix.clear()
        self.pool.reset()
        self.cmp_pool.reset()
        self.lengths[:] = 0
        self._tables_dirty = True

    def _copy_cmp_page(self, src: int, dst: int) -> None:
        """Device copy of one compressed page (all layers, K and V)."""
        layers = dict(self.data["layers"])
        for key in ("cmp_k_pages", "cmp_v_pages"):
            if key in layers:
                layers[key] = layers[key].at[:, dst].set(layers[key][:, src])
        self.data = dict(self.data, layers=layers)

    # ----------------------------------------------------------- device IO
    def views(self, slots=None, *, layer: int | None = None,
              batch_size: int | None = None) -> dict:
        """The one read accessor over the paged state.

        ``slots``:
          None       -> device tables for ALL slots (cached until dirty):
                        {"page_table": (B, max_pages), "cmp_table":
                        (B, max_cmp_pages), "write_floor": (B,),
                        "cmp_write_floor": (B,)} — the operand of the decode
                        / fused-tick jits.  Write floors mark the first
                        writable row per slot (everything below is a shared
                        prefix page, routed to the dump page on write).
          int        -> the same dict with unbatched per-slot rows.
          sequence   -> a batched dict for those slots, padded to
                        ``batch_size`` with all-dump-page rows (inert
                        slots) — the fixed-shape operand of the batched
                        prefill jit.

        ``layer=k`` additionally materialises dense contiguous K/V (+ cmp)
        views of layer ``k`` under "k"/"v" (+ "cmp_k"/"cmp_v") — the shape
        the dense cache stores directly (test/debug path: decode proper
        reads only the pages the NSA branches touch).
        """
        single = isinstance(slots, (int, np.integer))
        if slots is None:
            if self._tables_dirty:
                self._dev_tables = self._build_tables(range(self.n_slots),
                                                      self.n_slots)
                self._tables_dirty = False
            out = self._dev_tables
            if layer is None:
                return out
        else:
            idx = [int(slots)] if single else [int(s) for s in slots]
            bsz = batch_size if batch_size is not None else len(idx)
            if len(idx) > bsz:
                raise ValueError(f"{len(idx)} slots exceed batch size {bsz}")
            out = self._build_tables(idx, bsz)
        if layer is not None:
            out = dict(out, **self._gather_layer(out, layer))
        if single:
            out = {k: v[0] for k, v in out.items()}
        return out

    def _build_tables(self, slots, bsz: int) -> dict:
        pt = np.zeros((bsz, self.max_pages), np.int32)
        ct = np.zeros((bsz, self.max_cmp_pages), np.int32)
        wf = np.zeros((bsz,), np.int32)
        cwf = np.zeros((bsz,), np.int32)
        for i, s in enumerate(slots):
            pt[i] = self.tables[s].as_row()
            ct[i] = self.cmp_tables[s].as_row()
            wf[i] = self.tables[s].shared * self.page_size
            cwf[i] = self.cmp_tables[s].shared * self.page_size
        return {"page_table": jnp.asarray(pt), "cmp_table": jnp.asarray(ct),
                "write_floor": jnp.asarray(wf),
                "cmp_write_floor": jnp.asarray(cwf)}

    def _gather_layer(self, tables: dict, layer: int) -> dict:
        lc = jax.tree.map(lambda a: a[layer], self.data["layers"])
        rows = jnp.arange(self.max_pages * self.page_size)
        gk = jax.vmap(gather_rows, in_axes=(None, 0, None))
        out = {"k": gk(lc["k_pages"], tables["page_table"], rows),
               "v": gk(lc["v_pages"], tables["page_table"], rows)}
        if "cmp_k_pages" in lc:
            crows = jnp.arange(self.max_cmp_pages * self.page_size)
            out["cmp_k"] = gk(lc["cmp_k_pages"], tables["cmp_table"], crows)
            out["cmp_v"] = gk(lc["cmp_v_pages"], tables["cmp_table"], crows)
        return out

    def utilization(self) -> dict:
        return {"raw": self.pool.utilization(),
                "cmp": self.cmp_pool.utilization()}

    # ----------------------------------------- deprecated view spellings
    def _views_deprecated(self, old: str, *args, **kwargs):
        warnings.warn(f"PagedNSACache.{old}() is deprecated; use "
                      f"views(slots=..., layer=...)", DeprecationWarning,
                      stacklevel=3)
        return self.views(*args, **kwargs)

    def device_tables(self) -> dict:
        """Deprecated: ``views()``."""
        return self._views_deprecated("device_tables")

    def slot_tables(self, slot: int) -> dict:
        """Deprecated: ``views(slot)``."""
        return self._views_deprecated("slot_tables", slot)

    def slot_tables_batch(self, slots, batch_size: int | None = None) -> dict:
        """Deprecated: ``views(slots, batch_size=...)``."""
        return self._views_deprecated("slot_tables_batch", slots,
                                      batch_size=batch_size)

    _DENSE_KEYS = ("k", "v", "cmp_k", "cmp_v")

    def gather_view(self, slot: int, layer: int = 0) -> dict:
        """Deprecated: ``views(slot, layer=...)``."""
        out = self._views_deprecated("gather_view", slot, layer=layer)
        return {k: out[k] for k in self._DENSE_KEYS if k in out}

    def gather_views(self, slots, layer: int = 0) -> dict:
        """Deprecated: ``views(slots, layer=...)``."""
        out = self._views_deprecated("gather_views", slots, layer=layer)
        return {k: out[k] for k in self._DENSE_KEYS if k in out}
