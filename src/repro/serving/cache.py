"""PagedNSACache: paged raw-KV + compressed-token storage for all layers.

Device state is a pytree of per-layer page pools (stacked over layers so the
transformer's ``lax.scan`` carries it like the dense cache), plus two shared
page tables (raw and compressed) — one allocation serves every layer, as in
MaxText's page_manager / vLLM.

Two pools because the two token kinds grow at different rates: raw pages at
1 row/token, compressed pages at 1 row per ``cmp_stride`` tokens.  Page size
is ``nsa.block_size`` for both, so the NSA selected branch addresses physical
pages directly.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.core.paging import gather_rows
from repro.serving.pages import PagePool, PageTable, tables_array


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class PagedNSACache:
    """Paged decode cache for ``n_slots`` concurrent sequences.

    ``data`` is the device pytree handed to the jitted model functions
    (ownership transfers in/out each step so buffers can be donated).
    """

    def __init__(self, cfg, n_slots: int, max_len: int, *,
                 num_pages: int | None = None):
        if cfg.family in ("ssm", "hybrid", "encdec"):
            raise NotImplementedError(
                f"paged KV serving needs an attention cache; family "
                f"'{cfg.family}' has recurrent/cross-attn state")
        self.cfg = cfg
        self.page_size = cfg.nsa.block_size
        self.n_slots = n_slots
        self.max_len = max_len
        self.max_pages = _ceil_div(max_len, self.page_size)
        # compressed tokens per slot at full depth, padded to whole pages
        self.max_cmp_tokens = cfg.nsa.num_cmp_blocks(max_len)
        self.max_cmp_pages = _ceil_div(self.max_cmp_tokens, self.page_size)
        # +1 everywhere: page 0 is the reserved dump page
        self.num_pages = (num_pages if num_pages is not None
                          else n_slots * self.max_pages + 1)
        self.num_cmp_pages = n_slots * self.max_cmp_pages + 1

        self.pool = PagePool(self.num_pages, self.page_size)
        self.cmp_pool = PagePool(self.num_cmp_pages, self.page_size)
        self.tables = [PageTable(self.max_pages) for _ in range(n_slots)]
        self.cmp_tables = [PageTable(self.max_cmp_pages) for _ in range(n_slots)]
        self.lengths = np.zeros((n_slots,), np.int64)   # tokens written

        self.data = transformer.init_lm_paged_cache(
            cfg, self.num_pages, self.num_cmp_pages)
        self._tables_dirty = True
        self._dev_tables = None

    # ------------------------------------------------------------- alloc
    def pages_needed(self, capacity_tokens: int) -> tuple[int, int]:
        """(raw, cmp) page count to hold ``capacity_tokens`` for one slot."""
        raw = _ceil_div(capacity_tokens, self.page_size)
        cmp_tokens = self.cfg.nsa.num_cmp_blocks(capacity_tokens)
        return raw, _ceil_div(cmp_tokens, self.page_size)

    def can_admit(self, capacity_tokens: int) -> bool:
        raw, cmp = self.pages_needed(capacity_tokens)
        return (raw <= self.max_pages and cmp <= self.max_cmp_pages
                and self.pool.can_alloc(raw) and self.cmp_pool.can_alloc(cmp))

    def alloc_slot(self, slot: int, capacity_tokens: int) -> bool:
        """Reserve the slot's full worst-case page budget up front (simple
        admission control: an admitted request can never OOM mid-flight)."""
        raw_n, cmp_n = self.pages_needed(capacity_tokens)
        if raw_n > self.max_pages or cmp_n > self.max_cmp_pages:
            raise ValueError(
                f"request needs {raw_n} pages > slot capacity {self.max_pages} "
                f"(max_len={self.max_len})")
        raw = self.pool.alloc(raw_n)
        if raw is None:
            return False
        cmp = self.cmp_pool.alloc(cmp_n)
        if cmp is None:
            self.pool.free(raw)
            return False
        self.tables[slot].assign(raw)
        self.cmp_tables[slot].assign(cmp)
        self.lengths[slot] = 0
        self._tables_dirty = True
        return True

    def free_slot(self, slot: int) -> None:
        self.pool.free(self.tables[slot].clear())
        self.cmp_pool.free(self.cmp_tables[slot].clear())
        self.lengths[slot] = 0
        self._tables_dirty = True

    def reset(self) -> None:
        for s in range(self.n_slots):
            self.tables[s].clear()
            self.cmp_tables[s].clear()
        self.pool.reset()
        self.cmp_pool.reset()
        self.lengths[:] = 0
        self._tables_dirty = True

    # ----------------------------------------------------------- device IO
    def device_tables(self) -> dict:
        """{"page_table": (B, max_pages), "cmp_table": (B, max_cmp_pages)}."""
        if self._tables_dirty:
            self._dev_tables = {
                "page_table": tables_array(self.tables),
                "cmp_table": tables_array(self.cmp_tables),
            }
            self._tables_dirty = False
        return self._dev_tables

    def slot_tables(self, slot: int) -> dict:
        dev = self.device_tables()
        return {"page_table": dev["page_table"][slot],
                "cmp_table": dev["cmp_table"][slot]}

    def slot_tables_batch(self, slots, batch_size: int | None = None) -> dict:
        """Batched {"page_table": (B, max_pages), "cmp_table": …} for the
        given slots, padded to ``batch_size`` with all-dump-page rows (inert
        slots) — the fixed-shape operand of the batched prefill jit."""
        bsz = batch_size if batch_size is not None else len(slots)
        if len(slots) > bsz:
            raise ValueError(f"{len(slots)} slots exceed batch size {bsz}")
        pt = np.zeros((bsz, self.max_pages), np.int32)
        ct = np.zeros((bsz, self.max_cmp_pages), np.int32)
        for i, s in enumerate(slots):
            pt[i] = self.tables[s].as_row()
            ct[i] = self.cmp_tables[s].as_row()
        return {"page_table": jnp.asarray(pt), "cmp_table": jnp.asarray(ct)}

    def utilization(self) -> dict:
        return {"raw": self.pool.utilization(),
                "cmp": self.cmp_pool.utilization()}

    # -------------------------------------------------- contiguous views
    def gather_view(self, slot: int, layer: int = 0) -> dict:
        """Dense (max_len, h_k, d) K/V (+ cmp) views of one slot — the shape
        the dense cache stores directly.  Test/debug path: materialises the
        whole slot, whereas decode reads only the pages the NSA branches
        touch."""
        return {k: v[0] for k, v in self.gather_views([slot], layer).items()}

    def gather_views(self, slots, layer: int = 0) -> dict:
        """Batched ``gather_view``: dense (B, max_len, h_k, d) K/V (+ cmp)
        views for the given slots — the (B, …) shape the batched decode /
        parity tests consume."""
        t = self.slot_tables_batch(list(slots))
        lc = jax.tree.map(lambda a: a[layer], self.data["layers"])
        rows = jnp.arange(self.max_pages * self.page_size)
        gk = jax.vmap(gather_rows, in_axes=(None, 0, None))
        out = {"k": gk(lc["k_pages"], t["page_table"], rows),
               "v": gk(lc["v_pages"], t["page_table"], rows)}
        if "cmp_k_pages" in lc:
            crows = jnp.arange(self.max_cmp_pages * self.page_size)
            out["cmp_k"] = gk(lc["cmp_k_pages"], t["cmp_table"], crows)
            out["cmp_v"] = gk(lc["cmp_v_pages"], t["cmp_table"], crows)
        return out
