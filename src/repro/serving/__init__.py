"""repro.serving — paged NSA KV-cache + continuous-batching serving.

Layout:
  pages.py         ref-counted KV page pool (PageLease) + per-slot tables
  cache.py         PagedNSACache: raw-token and compressed-token pages
  prefix.py        radix prefix cache: copy-on-write page sharing across
                   requests with a common prompt prefix
  scheduler.py     admission queue (token-budget policy), slot recycling,
                   page reclamation
  engine.py        fused mixed tick: chunked prefill co-scheduled with
                   batched decode over per-slot positions, one dispatch/tick
  sharded.py       ShardedEngine over a ("data","model") mesh: KV-head-
                   sharded page pools, slot-sharded engine replicas, one
                   shard_mapped dispatch (Engine(mesh=...) routes here)
  async_engine.py  asyncio request loop with per-request token streaming
"""
from repro.serving.async_engine import AsyncEngine
from repro.serving.cache import PagedNSACache
from repro.serving.engine import Engine
from repro.serving.pages import PageLease, PagePool, PageTable
from repro.serving.prefix import PrefixCache, PrefixMatch
from repro.serving.scheduler import Request, Scheduler
from repro.serving.sharded import MeshLayoutError, ShardedEngine

__all__ = ["AsyncEngine", "Engine", "MeshLayoutError", "PageLease",
           "PagePool", "PageTable", "PagedNSACache", "PrefixCache",
           "PrefixMatch", "Request", "Scheduler", "ShardedEngine"]
