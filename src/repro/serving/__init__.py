"""repro.serving — paged NSA KV-cache + continuous-batching serving.

Layout:
  pages.py      fixed-size KV page pool + per-slot page tables
  cache.py      PagedNSACache: raw-token and compressed-token pages
  scheduler.py  admission queue, slot recycling, page reclamation
  engine.py     chunked prefill + batched decode over per-slot positions
"""
from repro.serving.cache import PagedNSACache
from repro.serving.engine import Engine
from repro.serving.pages import PagePool, PageTable
from repro.serving.scheduler import Request, Scheduler

__all__ = ["Engine", "PagePool", "PageTable", "PagedNSACache", "Request",
           "Scheduler"]
