"""repro.serving — paged NSA KV-cache + continuous-batching serving.

Layout:
  pages.py         fixed-size KV page pool + per-slot page tables
  cache.py         PagedNSACache: raw-token and compressed-token pages
  scheduler.py     admission queue (token-budget policy), slot recycling,
                   page reclamation
  engine.py        fused mixed tick: chunked prefill co-scheduled with
                   batched decode over per-slot positions, one dispatch/tick
  async_engine.py  asyncio request loop with per-request token streaming
"""
from repro.serving.async_engine import AsyncEngine
from repro.serving.cache import PagedNSACache
from repro.serving.engine import Engine
from repro.serving.pages import PagePool, PageTable
from repro.serving.scheduler import Request, Scheduler

__all__ = ["AsyncEngine", "Engine", "PagePool", "PageTable", "PagedNSACache",
           "Request", "Scheduler"]
