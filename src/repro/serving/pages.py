"""Fixed-size KV page pool and per-slot page tables.

Pages are the unit of KV-cache allocation (page size = ``nsa.block_size``
tokens, so one NSA selected block == one physical page).  Allocation is
host-side (the scheduler runs on the host anyway); the device sees only
int32 page-table arrays, so jitted model functions never recompile as
traffic changes.

Pages are REF-COUNTED: ``try_alloc`` hands out a :class:`PageLease` whose
pages start at refcount 1, ``share(pages)`` adds a reference (prefix-cache
sharing: N slots + the radix trie can all point at one physical copy), and
``release(pages)`` drops one — a page returns to the free list only when its
last reference goes.  The pre-lease ``alloc``/``free`` spellings remain as
one-release deprecation shims.

Page 0 of every pool is a reserved dump page: idle slots and masked writes
are routed there, which keeps all scatters unconditional (no ragged shapes).

The device-side row addressing lives in ``repro.core.paging`` (kernels and
model layers use it too); re-exported here for convenience.
"""
from __future__ import annotations

import collections
import warnings

import jax.numpy as jnp
import numpy as np

from repro.core.paging import gather_rows, scatter_rows

__all__ = ["PageLease", "PagePool", "PageTable", "tables_array",
           "gather_rows", "scatter_rows"]


class PageLease:
    """Handle over a batch of freshly allocated pages (refcount 1 each).

    ``lease.pages`` is the page-id list; ``lease.release()`` drops the
    lease's reference on every page exactly once (idempotent, so unwind
    paths can call it unconditionally).  Ownership of individual references
    can instead transfer to a page table — see ``PageLease.take()``.
    """

    __slots__ = ("pool", "_pages", "_live")

    def __init__(self, pool: "PagePool", pages: list[int]):
        self.pool = pool
        self._pages = list(pages)
        self._live = True

    @property
    def pages(self) -> list[int]:
        return list(self._pages)

    def __len__(self) -> int:
        return len(self._pages)

    def __iter__(self):
        return iter(self._pages)

    def take(self) -> list[int]:
        """Transfer reference ownership out of the lease: the caller is now
        responsible for ``pool.release(pages)``; a later ``lease.release()``
        is a no-op."""
        self._live = False
        return list(self._pages)

    def release(self) -> None:
        """Drop the lease's reference on every page (idempotent)."""
        if self._live:
            self._live = False
            self.pool.release(self._pages)


class PagePool:
    """Host-side ref-counted allocator over a fixed set of physical pages."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the reserved dump page)")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free = collections.deque(range(1, num_pages))
        self._refs: dict[int, int] = {}          # page id -> live references

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def used(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def utilization(self) -> float:
        return self.used / max(self.num_pages - 1, 1)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def refcount(self, page: int) -> int:
        """Live references on ``page`` (0 = free / never allocated)."""
        return self._refs.get(int(page), 0)

    # ------------------------------------------------------------ leases
    def try_alloc(self, n: int) -> PageLease | None:
        """Pop ``n`` pages at refcount 1 behind a :class:`PageLease`;
        None (and no side effect) if the pool is short."""
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return PageLease(self, pages)

    def share(self, pages) -> None:
        """Add one reference to each (already-allocated) page."""
        for p in pages:
            p = int(p)
            if self._refs.get(p, 0) < 1:
                raise ValueError(f"sharing unallocated page id {p}")
            self._refs[p] += 1

    def release(self, pages) -> None:
        """Drop one reference per page; pages return to the free list only
        at refcount zero (shared prefix pages survive slot release)."""
        for p in pages:
            p = int(p)
            if not 1 <= p < self.num_pages:
                raise ValueError(f"releasing invalid page id {p}")
            refs = self._refs.get(p, 0)
            if refs < 1:
                raise ValueError(f"releasing page id {p} with no live refs")
            if refs == 1:
                del self._refs[p]
                self._free.append(p)
            else:
                self._refs[p] = refs - 1

    def reset(self) -> None:
        self._free = collections.deque(range(1, self.num_pages))
        self._refs.clear()

    # ----------------------------------------------- deprecated spellings
    def alloc(self, n: int) -> list[int] | None:
        """Deprecated: use ``try_alloc`` (PageLease handle API)."""
        warnings.warn(
            "PagePool.alloc is deprecated; use try_alloc() -> PageLease "
            "(refcount-aware)", DeprecationWarning, stacklevel=2)
        lease = self.try_alloc(n)
        return None if lease is None else lease.take()

    def free(self, pages) -> None:
        """Deprecated: use ``release`` (drops one reference per page)."""
        warnings.warn(
            "PagePool.free is deprecated; use release() (refcount-aware)",
            DeprecationWarning, stacklevel=2)
        self.release(pages)


class PageTable:
    """Per-slot logical-block -> physical-page mapping (host side).

    ``shared`` counts the leading pages aliased from the prefix cache: they
    are read-only for this slot (the device write path routes positions
    below ``shared * page_size`` to the dump page), and ``clear()`` returns
    them together with the private tail so each released reference is
    dropped exactly once.
    """

    def __init__(self, max_pages: int):
        self.max_pages = max_pages
        self.pages: list[int] = []
        self.shared = 0                     # leading pages aliased (read-only)

    def assign(self, pages: list[int], shared: int = 0) -> None:
        if len(pages) > self.max_pages:
            raise ValueError(
                f"{len(pages)} pages exceed slot capacity {self.max_pages}")
        if not 0 <= shared <= len(pages):
            raise ValueError(f"shared prefix {shared} out of range")
        self.pages = list(pages)
        self.shared = shared

    def clear(self) -> list[int]:
        pages, self.pages = self.pages, []
        self.shared = 0
        return pages

    def as_row(self) -> np.ndarray:
        """Dense (max_pages,) int32 row; unassigned entries -> dump page 0."""
        row = np.zeros((self.max_pages,), np.int32)
        row[: len(self.pages)] = self.pages
        return row


def tables_array(tables: list[PageTable]) -> jnp.ndarray:
    """Stack per-slot tables into the device-side (n_slots, max_pages) array."""
    return jnp.asarray(np.stack([t.as_row() for t in tables]))
