"""Fixed-size KV page pool and per-slot page tables.

Pages are the unit of KV-cache allocation (page size = ``nsa.block_size``
tokens, so one NSA selected block == one physical page).  Allocation is
host-side (the scheduler runs on the host anyway); the device sees only
int32 page-table arrays, so jitted model functions never recompile as
traffic changes.

Page 0 of every pool is a reserved dump page: idle slots and masked writes
are routed there, which keeps all scatters unconditional (no ragged shapes).

The device-side row addressing lives in ``repro.core.paging`` (kernels and
model layers use it too); re-exported here for convenience.
"""
from __future__ import annotations

import collections

import jax.numpy as jnp
import numpy as np

from repro.core.paging import gather_rows, scatter_rows

__all__ = ["PagePool", "PageTable", "tables_array", "gather_rows",
           "scatter_rows"]


class PagePool:
    """Host-side allocator over a fixed set of physical pages."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is the reserved dump page)")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free = collections.deque(range(1, num_pages))

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def used(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def utilization(self) -> float:
        return self.used / max(self.num_pages - 1, 1)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` pages; None (and no side effect) if the pool is short."""
        if n > len(self._free):
            return None
        return [self._free.popleft() for _ in range(n)]

    def free(self, pages) -> None:
        for p in pages:
            if not 1 <= p < self.num_pages:
                raise ValueError(f"freeing invalid page id {p}")
            self._free.append(int(p))

    def reset(self) -> None:
        self._free = collections.deque(range(1, self.num_pages))


class PageTable:
    """Per-slot logical-block -> physical-page mapping (host side)."""

    def __init__(self, max_pages: int):
        self.max_pages = max_pages
        self.pages: list[int] = []

    def assign(self, pages: list[int]) -> None:
        if len(pages) > self.max_pages:
            raise ValueError(
                f"{len(pages)} pages exceed slot capacity {self.max_pages}")
        self.pages = list(pages)

    def clear(self) -> list[int]:
        pages, self.pages = self.pages, []
        return pages

    def as_row(self) -> np.ndarray:
        """Dense (max_pages,) int32 row; unassigned entries -> dump page 0."""
        row = np.zeros((self.max_pages,), np.int32)
        row[: len(self.pages)] = self.pages
        return row


def tables_array(tables: list[PageTable]) -> jnp.ndarray:
    """Stack per-slot tables into the device-side (n_slots, max_pages) array."""
    return jnp.asarray(np.stack([t.as_row() for t in tables]))
