"""Continuous-batching scheduler: admission queue, slot recycling, page
reclamation.

Requests carry variable-length prompts.  A request is admitted when a decode
slot is free AND the page pools can cover its full worst-case footprint
(prompt rounded up to the prefill chunk + max_new tokens) — reserving up
front means an admitted request can never OOM mid-flight.  On EOS /
``max_new`` the slot is recycled and its pages return to the pool
immediately, letting the next queued request in on the same engine tick.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Optional

import numpy as np

from repro.serving.cache import PagedNSACache

_RID = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                      # (S,) int32, any length
    max_new: int = 16
    eos_id: Optional[int] = None
    rid: int = dataclasses.field(default_factory=lambda: next(_RID))
    out: list = dataclasses.field(default_factory=list)
    state: str = "queued"                   # queued | active | done
    slot: Optional[int] = None
    # ---- per-request timeline (host wall clocks, stamped in this order) --
    submit_t: float = dataclasses.field(default_factory=time.time)
    admit_t: Optional[float] = None         # slot + pages granted
    first_chunk_t: Optional[float] = None   # first prefill chunk dispatched
    first_token_t: Optional[float] = None   # stamped per request, AFTER its
    finish_t: Optional[float] = None        # first token is on host
    # tokens satisfied from the prefix cache at admission (block-aligned);
    # prefill starts here instead of 0, shrinking chunk accounting and TTFT
    cached_tokens: int = 0
    # ---- bounded retention (see Scheduler.release) ----------------------
    prompt_len: int = 0
    n_out: Optional[int] = None             # token count kept after eviction
    out_evicted: bool = False

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        self.prompt_len = int(self.prompt.size)

    @property
    def done(self) -> bool:
        return self.state == "done"

    @property
    def num_out(self) -> int:
        """Output token count — survives token-list eviction."""
        return self.n_out if self.out_evicted else len(self.out)

    def timeline(self) -> dict:
        """Stamped lifecycle events in order (absent stamps omitted):
        submit <= admit <= first_chunk <= first_token <= finish."""
        stamps = (("submit", self.submit_t), ("admit", self.admit_t),
                  ("first_chunk", self.first_chunk_t),
                  ("first_token", self.first_token_t),
                  ("finish", self.finish_t))
        return {k: t for k, t in stamps if t is not None}


class Scheduler:
    """Maps queued requests onto cache slots; frees pages on completion."""

    def __init__(self, cache: PagedNSACache, prefill_chunk: int, *,
                 retain_outputs: int | None = None, prefix=None):
        self.cache = cache
        self.prefill_chunk = prefill_chunk
        # optional repro.serving.prefix.PrefixCache: admit() matches each
        # head-of-queue prompt against it so cached blocks skip prefill
        self.prefix = prefix
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[Request | None] = [None] * cache.n_slots
        self.finished: list[Request] = []
        # bounded retention for long-running service loops: only the newest
        # ``retain_outputs`` finished requests keep their token lists; older
        # ones are evicted down to counts + timeline (None = keep all)
        self.retain_outputs = retain_outputs
        self._retained: collections.deque[Request] = collections.deque()
        # called with the request on release, after its slot/pages are freed
        # — the engine hooks this to zero the slot's per-slot decode state
        # (_last_tokens), so a recycled slot never inherits a stale token
        self.on_release = None

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> Request:
        if len(req.prompt) + req.max_new > self.cache.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} exceeds engine max_len {self.cache.max_len}")
        # the chunk-rounded footprint must also fit one slot's page budget
        # AND the (possibly smaller) physical pools, otherwise admit() could
        # never place it (reject here, per request, rather than wedging the
        # engine in an unadmittable busy-loop later)
        raw_n, cmp_n = self.cache.pages_needed(self.capacity_tokens(req))
        raw_cap = min(self.cache.max_pages, self.cache.pool.num_pages - 1)
        cmp_cap = min(self.cache.max_cmp_pages,
                      self.cache.cmp_pool.num_pages - 1)
        if raw_n > raw_cap or cmp_n > cmp_cap:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} rounded to "
                f"whole prefill chunks of {self.prefill_chunk} needs "
                f"{raw_n}+{cmp_n} pages > capacity {raw_cap}+{cmp_cap} "
                f"(max_len={self.cache.max_len}; raise max_len/num_pages or "
                f"lower prefill_chunk)")
        self.queue.append(req)
        return req

    def capacity_tokens(self, req: Request) -> int:
        """Worst-case rows the slot must address: the prompt rounded up to
        whole prefill chunks (padded chunk tails still write rows), plus the
        decode budget."""
        c = self.prefill_chunk
        padded = -(-len(req.prompt) // c) * c
        return max(padded, len(req.prompt) + req.max_new)

    def chunk_tokens(self, req: Request) -> int:
        """Prefill-chunk tokens one engine tick spends on this request (the
        fused tick advances every prefilling slot by at most one chunk).
        Prefix-cached tokens are never prefilled, so they don't count."""
        return min(self.prefill_chunk, req.prompt_len - req.cached_tokens)

    # ---------------------------------------------------------- admission
    def admit(self, limit: int | None = None, *,
              token_budget: int | None = None,
              tokens_in_flight: int = 0) -> list[Request]:
        """Move queued requests into free slots while pages allow (FIFO —
        no head-of-line bypass, so admission latency stays predictable).

        ``limit`` caps the admission batch (e.g. to bound the chunk count a
        single long prompt imposes on co-admitted short ones in the
        sequential engine).

        ``token_budget`` is the per-tick prefill token budget: admission
        stops once ``tokens_in_flight`` (chunk tokens of requests already
        mid-prefill, supplied by the engine) plus the next request's first
        chunk would exceed it.  Because per-request chunk tokens only shrink
        as prefill progresses, the invariant "prefill chunk tokens per tick
        <= token_budget" then holds for every subsequent tick, which bounds
        the decode latency a co-scheduled prefill can add.  A request is
        always admitted when nothing is in flight (a budget below one chunk
        must throttle, not wedge, the queue)."""
        admitted = []
        in_flight = tokens_in_flight
        while self.queue and (limit is None or len(admitted) < limit):
            try:
                slot = self.slots.index(None)
            except ValueError:
                break
            req = self.queue[0]
            # longest cached block-aligned prefix, refs pinned; exactly one
            # of alloc_slot(prefix=match) / match.cancel() consumes it
            match = (self.prefix.match(req.prompt)
                     if self.prefix is not None else None)
            cached = match.tokens if match is not None else 0
            first_chunk = min(self.prefill_chunk, req.prompt_len - cached)
            if (token_budget is not None and in_flight > 0
                    and in_flight + first_chunk > token_budget):
                if match is not None:
                    match.cancel()
                break
            if not self.cache.alloc_slot(slot, self.capacity_tokens(req),
                                         prefix=match):
                break   # alloc_slot cancelled the match's pinned refs
            self.queue.popleft()
            req.state, req.slot = "active", slot
            req.cached_tokens = cached
            req.admit_t = time.time()
            self.slots[slot] = req
            admitted.append(req)
            in_flight += first_chunk
        return admitted

    def release(self, req: Request) -> None:
        req.state = "done"
        req.finish_t = time.time()
        self.cache.free_slot(req.slot)
        self.slots[req.slot] = None
        self.finished.append(req)
        # bounded retention: evict the oldest finished requests' token lists
        # (prompt array included — the big allocations) past the cap, keeping
        # counts + the timeline so summaries/latency percentiles still work.
        # Without this an AsyncEngine serving indefinitely grows without
        # bound (scheduler.finished is never pruned).
        if self.retain_outputs is not None:
            self._retained.append(req)
            while len(self._retained) > self.retain_outputs:
                old = self._retained.popleft()
                old.n_out = len(old.out)
                old.out = []
                old.prompt = np.empty((0,), np.int32)
                old.out_evicted = True
        if self.on_release is not None:
            self.on_release(req)

    # ------------------------------------------------------------- state
    @property
    def active(self) -> list[Request]:
        return [r for r in self.slots if r is not None]

    @property
    def pending(self) -> int:
        return len(self.queue)

    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.slots)
