"""Continuous-batching scheduler: admission queue, slot recycling, page
reclamation.

Requests carry variable-length prompts.  A request is admitted when a decode
slot is free AND the page pools can cover its full worst-case footprint
(prompt rounded up to the prefill chunk + max_new tokens) — reserving up
front means an admitted request can never OOM mid-flight.  On EOS /
``max_new`` the slot is recycled and its pages return to the pool
immediately, letting the next queued request in on the same engine tick.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Optional

import numpy as np

from repro.serving.cache import PagedNSACache

_RID = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                      # (S,) int32, any length
    max_new: int = 16
    eos_id: Optional[int] = None
    rid: int = dataclasses.field(default_factory=lambda: next(_RID))
    out: list = dataclasses.field(default_factory=list)
    state: str = "queued"                   # queued | active | done
    slot: Optional[int] = None
    submit_t: float = dataclasses.field(default_factory=time.time)
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")

    @property
    def done(self) -> bool:
        return self.state == "done"


class Scheduler:
    """Maps queued requests onto cache slots; frees pages on completion."""

    def __init__(self, cache: PagedNSACache, prefill_chunk: int):
        self.cache = cache
        self.prefill_chunk = prefill_chunk
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[Request | None] = [None] * cache.n_slots
        self.finished: list[Request] = []

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> Request:
        if len(req.prompt) + req.max_new > self.cache.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} exceeds engine max_len {self.cache.max_len}")
        # the chunk-rounded footprint must also fit one slot's page budget
        # AND the (possibly smaller) physical pools, otherwise admit() could
        # never place it (reject here, per request, rather than wedging the
        # engine in an unadmittable busy-loop later)
        raw_n, cmp_n = self.cache.pages_needed(self.capacity_tokens(req))
        raw_cap = min(self.cache.max_pages, self.cache.pool.num_pages - 1)
        cmp_cap = min(self.cache.max_cmp_pages,
                      self.cache.cmp_pool.num_pages - 1)
        if raw_n > raw_cap or cmp_n > cmp_cap:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} rounded to "
                f"whole prefill chunks of {self.prefill_chunk} needs "
                f"{raw_n}+{cmp_n} pages > capacity {raw_cap}+{cmp_cap} "
                f"(max_len={self.cache.max_len}; raise max_len/num_pages or "
                f"lower prefill_chunk)")
        self.queue.append(req)
        return req

    def capacity_tokens(self, req: Request) -> int:
        """Worst-case rows the slot must address: the prompt rounded up to
        whole prefill chunks (padded chunk tails still write rows), plus the
        decode budget."""
        c = self.prefill_chunk
        padded = -(-len(req.prompt) // c) * c
        return max(padded, len(req.prompt) + req.max_new)

    # ---------------------------------------------------------- admission
    def admit(self, limit: int | None = None) -> list[Request]:
        """Move queued requests into free slots while pages allow (FIFO —
        no head-of-line bypass, so admission latency stays predictable).

        Everything admitted on one call is prefilled TOGETHER by the
        engine's batched chunk jit, so the returned list is the admission
        batch; ``limit`` caps it (e.g. to bound the chunk count a single
        long prompt imposes on co-admitted short ones)."""
        admitted = []
        while self.queue and (limit is None or len(admitted) < limit):
            try:
                slot = self.slots.index(None)
            except ValueError:
                break
            req = self.queue[0]
            if not self.cache.alloc_slot(slot, self.capacity_tokens(req)):
                break
            self.queue.popleft()
            req.state, req.slot = "active", slot
            self.slots[slot] = req
            admitted.append(req)
        return admitted

    def release(self, req: Request) -> None:
        req.state = "done"
        req.finish_t = time.time()
        self.cache.free_slot(req.slot)
        self.slots[req.slot] = None
        self.finished.append(req)

    # ------------------------------------------------------------- state
    @property
    def active(self) -> list[Request]:
        return [r for r in self.slots if r is not None]

    @property
    def pending(self) -> int:
        return len(self.queue)

    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.slots)
