"""Mesh-sharded paged serving: KV-head-sharded page pools, slot-sharded
engine replicas, one ``shard_map``ped dispatch per tick.

Layout over a ``("data", "model")`` mesh of ``d x m`` devices:

* **model axis (tensor parallelism).**  The raw and compressed page pools
  shard on their KV-head dim — NSA's compression / selection / sliding
  branches are all per-kv-head independent, and GQA groups q-heads
  kv-major, so a contiguous block of ``n_kv_heads/m`` KV heads plus its
  ``n_heads/m`` query heads is a closed sub-problem.  The attention
  projections shard to match (``parallel.partition.serve_param_specs``);
  everything else (embeddings, norms, MLP/MoE, the headless NSA compression
  MLPs) is replicated, so ONE ``psum`` per attention out-projection is the
  only model-axis collective.  KV pages never cross the mesh.

* **data axis (engine replicas).**  Slots shard over "data": replica ``r``
  owns global slots ``[r*n_local, (r+1)*n_local)`` and its own page pools,
  page tables and radix prefix cache.  Page ids in every table are
  replica-LOCAL: the global pool arrays concatenate the replica slabs on
  the page dim and shard it over "data", so under ``shard_map`` each data
  shard sees exactly its own slab and local ids address it directly — each
  replica keeps its own dump page 0.  Admission stays host-side and global
  (one FIFO scheduler over the slot facade), so the jitted dispatch is
  shared while per-replica bookkeeping stays independent.

Per tick, the only arrays crossing the mesh are the (B,)-row operands in
(tokens, positions, page tables — a few int32 per slot) and the logits out
(psum over "model", slot-sharded over "data").  The Pallas paged-decode
kernel runs unmodified per shard on purely local pages.

Constructed via ``Engine(cfg, mesh=...)`` (a 1x1 mesh falls back to the
byte-identical single-device engine) or ``launch/serve --mesh dxm``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer
from repro.parallel import axes
from repro.parallel.partition import serve_param_specs
from repro.serving.cache import PagedNSACache
from repro.serving.engine import Engine
from repro.serving.prefix import PrefixCache

__all__ = ["MeshLayoutError", "ShardedEngine", "shard_map_compat",
           "valid_mesh_shapes"]


# ----------------------------------------------------------- compat helpers
def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions: 0.4.x takes ``check_rep``, newer
    releases renamed it ``check_vma`` (and moved the entry point out of
    ``jax.experimental``).  Replication checking is disabled — the psum over
    "model" makes the logits bitwise-replicated by construction, and 0.4.x's
    rep checker rejects the scatter/gather page ops."""
    try:
        from jax.experimental.shard_map import shard_map as sm
    except ImportError:                                  # moved in new jax
        sm = jax.shard_map
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)


# -------------------------------------------------------- mesh-shape checks
def valid_mesh_shapes(n_devices: int, n_kv_heads: int, n_slots: int
                      ) -> list[tuple[int, int]]:
    """All (data, model) factorizations of ``n_devices`` this engine can
    run: model must divide the KV heads, data must divide the slots."""
    out = []
    for m in range(1, n_devices + 1):
        if n_devices % m:
            continue
        d = n_devices // m
        if n_kv_heads % m == 0 and n_slots % d == 0:
            out.append((d, m))
    return out


class MeshLayoutError(ValueError):
    """Mesh shape incompatible with the sharding layout.  ``.valid`` carries
    every usable (data, model) factorization of the same device count."""

    def __init__(self, msg: str, valid: list[tuple[int, int]]):
        hint = ", ".join(f"{d}x{m}" for d, m in valid) or "none"
        super().__init__(f"{msg}; valid (data, model) shapes: {hint}")
        self.valid = valid


def _validate_mesh(mesh, cfg, n_slots: int) -> None:
    names = tuple(mesh.axis_names)
    if set(names) != {"data", "model"}:
        raise ValueError(
            f"ShardedEngine needs a ('data', 'model') mesh, got axes {names}")
    d, m = int(mesh.shape["data"]), int(mesh.shape["model"])
    valid = valid_mesh_shapes(d * m, cfg.n_kv_heads, n_slots)
    if cfg.n_kv_heads % m or cfg.n_heads % m:
        raise MeshLayoutError(
            f"model axis {m} does not divide n_kv_heads={cfg.n_kv_heads} "
            f"(n_heads={cfg.n_heads}) — KV pages shard per whole head",
            valid)
    if n_slots % d:
        raise MeshLayoutError(
            f"data axis {d} does not divide n_slots={n_slots} — slots shard "
            f"evenly over engine replicas", valid)


# --------------------------------------------------------------- page state
class _ReplicaCache(PagedNSACache):
    """Bookkeeping-only per-replica cache: local page pools, tables and
    lengths, NO device pytree (the facade owns one global sharded pytree).
    The copy-on-write of a prefix boundary compressed page routes to the
    facade at this replica's slab offset."""

    def __init__(self, cfg, n_slots, max_len, *, num_pages, facade, replica):
        super().__init__(cfg, n_slots, max_len, num_pages=num_pages,
                         alloc_data=False)
        self._facade = facade
        self._replica = replica

    def _copy_cmp_page(self, src: int, dst: int) -> None:
        self._facade._copy_cmp_page_global(self._replica, src, dst)


class _ShardedCache:
    """Slot-sharded facade over per-replica ``PagedNSACache`` bookkeeping
    plus ONE mesh-sharded device pytree.

    Global slot ``s`` lives on replica ``s // n_local`` as local slot
    ``s % n_local`` — the same rows the "data" axis assigns to device row
    ``s // n_local``, so host bookkeeping and device sharding agree by
    construction.  The scheduler and engine only see the global surface
    (``n_slots`` slots, one ``lengths`` vector, one ``views()`` table set).
    """

    def __init__(self, cfg, n_slots: int, max_len: int, mesh, *,
                 num_pages: int | None = None):
        self.cfg = cfg
        self.mesh = mesh
        d = int(mesh.shape["data"])
        self.n_slots = n_slots
        self.n_local = n_slots // d
        self.n_replicas = d
        # ``num_pages`` is PER REPLICA (each replica's private pool)
        self.replicas = [
            _ReplicaCache(cfg, self.n_local, max_len, num_pages=num_pages,
                          facade=self, replica=r)
            for r in range(d)]
        r0 = self.replicas[0]
        self.page_size = r0.page_size
        self.max_len = r0.max_len
        self.max_pages = r0.max_pages
        self.max_cmp_tokens = r0.max_cmp_tokens
        self.max_cmp_pages = r0.max_cmp_pages
        self.num_pages = r0.num_pages          # per replica
        self.num_cmp_pages = r0.num_cmp_pages
        # the scheduler's submit-time capacity validation reads pool sizes;
        # replicas are identical, so replica 0 speaks for all of them
        self.pool = r0.pool
        self.cmp_pool = r0.cmp_pool
        self.prefix = None                     # set by the engine (a router)
        # ONE global lengths vector; each replica's ``lengths`` is a numpy
        # VIEW of its slice, so replica-local writes (alloc/free/reset) and
        # the engine's global reads always agree
        self.lengths = np.zeros((n_slots,), np.int64)
        for r, rep in enumerate(self.replicas):
            rep.lengths = self.lengths[r * self.n_local:
                                       (r + 1) * self.n_local]
        # global device pytree: replica pool slabs concatenated on the page
        # dim (sharded over "data" -> each shard sees its own slab, local
        # page ids address it directly), KV heads sharded over "model"
        self._data_spec = P(None, "data", None, "model", None)
        data = transformer.init_lm_paged_cache(
            cfg, d * self.num_pages, d * self.num_cmp_pages)
        self._shardings = jax.tree.map(
            lambda _: NamedSharding(mesh, self._data_spec), data)
        self.data = jax.device_put(data, self._shardings)
        self._dev_tables = None

    # ------------------------------------------------------------ routing
    def _route(self, slot: int) -> tuple[_ReplicaCache, int]:
        return self.replicas[slot // self.n_local], slot % self.n_local

    def pages_needed(self, capacity_tokens: int) -> tuple[int, int]:
        return self.replicas[0].pages_needed(capacity_tokens)

    def can_admit(self, capacity_tokens: int, prefix=None) -> bool:
        return any(rep.can_admit(capacity_tokens, prefix)
                   for rep in self.replicas)

    def alloc_slot(self, slot: int, capacity_tokens: int, *,
                   prefix=None) -> bool:
        rep, ls = self._route(slot)
        return rep.alloc_slot(ls, capacity_tokens, prefix=prefix)

    def free_slot(self, slot: int) -> None:
        rep, ls = self._route(slot)
        rep.free_slot(ls)

    def reset(self) -> None:
        for rep in self.replicas:
            rep.reset()                # clears each replica's prefix trie too

    def utilization(self) -> dict:
        us = [rep.utilization() for rep in self.replicas]
        return {"raw": max(u["raw"] for u in us),
                "cmp": max(u["cmp"] for u in us)}

    # ---------------------------------------------------------- device IO
    def views(self, slots=None, *, layer=None, batch_size=None) -> dict:
        """Device tables for ALL slots (replica tables stacked in global
        slot order; ids stay replica-local — see class docstring).  The
        per-slot / dense-gather views are single-device debug accessors and
        are not exposed here."""
        if slots is not None or layer is not None:
            raise NotImplementedError(
                "sharded cache exposes only the all-slot device tables "
                "(views() with no arguments)")
        if self._dev_tables is None or any(rep._tables_dirty
                                           for rep in self.replicas):
            parts = [rep.views() for rep in self.replicas]
            self._dev_tables = {
                k: jnp.concatenate([pt[k] for pt in parts], axis=0)
                for k in parts[0]}
        return self._dev_tables

    def _copy_cmp_page_global(self, replica: int, src: int, dst: int) -> None:
        """Device copy of one compressed page inside ``replica``'s slab of
        the global arrays (all layers, K and V)."""
        off = replica * self.num_cmp_pages
        layers = dict(self.data["layers"])
        for key in ("cmp_k_pages", "cmp_v_pages"):
            if key in layers:
                layers[key] = layers[key].at[:, off + dst].set(
                    layers[key][:, off + src])
        # re-pin the sharding: .at[].set on a sharded array can come back
        # with a fresh layout, and the dispatch jit donates ``data``
        self.data = jax.device_put(dict(self.data, layers=layers),
                                   self._shardings)


# ------------------------------------------------------------ prefix router
class _PrefixRouter:
    """Routes prefix-cache calls to the replica that owns (or is about to
    receive) the slot.  ``Scheduler.admit`` picks the lowest free slot
    BEFORE matching, so peeking the same ``slots.index(None)`` here selects
    the replica whose pages the subsequent ``alloc_slot`` will alias."""

    def __init__(self, prefixes: list[PrefixCache], n_local: int):
        self.prefixes = prefixes
        self.n_local = n_local
        self._scheduler = None                 # bound by ShardedEngine

    def match(self, prompt):
        try:
            slot = self._scheduler.slots.index(None)
        except ValueError:
            return None
        return self.prefixes[slot // self.n_local].match(prompt)

    def insert(self, prompt, slot: int) -> int:
        return self.prefixes[slot // self.n_local].insert(
            prompt, slot % self.n_local)

    @property
    def blocks_cached(self) -> int:
        return sum(p.blocks_cached for p in self.prefixes)

    def clear(self) -> None:
        for p in self.prefixes:
            p.clear()


# ------------------------------------------------------------------- engine
class ShardedEngine(Engine):
    """``Engine`` over a ``("data", "model")`` mesh (see module docstring).

    Construct via ``Engine(cfg, ..., mesh=make_mesh((d, m), ("data",
    "model")))`` — ``Engine.__new__`` routes here whenever the mesh spans
    more than one device.  Fused-tick only: the sequential A/B engine is a
    single-device debugging path.
    """

    def __init__(self, cfg, n_slots: int = 4, max_len: int = 1024, *,
                 mesh=None, fused: bool = True, **kwargs):
        if mesh is None:
            raise ValueError("ShardedEngine requires mesh=")
        if not fused:
            raise NotImplementedError(
                "ShardedEngine is fused-tick only (fused=False is the "
                "single-device sequential A/B reference)")
        _validate_mesh(mesh, cfg, n_slots)
        self.mesh = mesh
        self.n_data = int(mesh.shape["data"])
        self.n_model = int(mesh.shape["model"])
        super().__init__(cfg, n_slots, max_len, fused=True, **kwargs)
        # place the (replicated-host) params per the serving layout:
        # attention projections head-sharded over "model", rest replicated
        specs = serve_param_specs(self.params, mesh)
        self.params = jax.device_put(
            self.params,
            jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                         is_leaf=lambda x: isinstance(x, P)))
        if isinstance(self._prefix, _PrefixRouter):
            self._prefix._scheduler = self.scheduler

    # --------------------------------------------------- construction hooks
    def _make_cache(self, cfg, n_slots, max_len, *, num_pages):
        return _ShardedCache(cfg, n_slots, max_len, self.mesh,
                             num_pages=num_pages)

    def _make_prefix(self):
        prefixes = []
        for rep in self.cache.replicas:
            pc = PrefixCache(rep)
            rep.prefix = pc        # replica-local pressure eviction
            prefixes.append(pc)
        return _PrefixRouter(prefixes, self.cache.n_local)

    def _build_dispatch(self, cfg) -> None:
        mesh, m = self.mesh, self.n_model
        # each model shard runs a contiguous KV-head block and its q-head
        # group as a closed sub-problem; head_dim is pinned so hd() survives
        # the head-count division
        cfg_local = dataclasses.replace(
            cfg, head_dim=cfg.hd(), n_heads=cfg.n_heads // m,
            n_kv_heads=cfg.n_kv_heads // m)
        psum_model = lambda t: jax.lax.psum(t, "model")
        # logical-axis annotations (``axes.shard``) inside the body must be
        # no-ops: sharding is fully explicit via shard_map specs here
        no_rules = {k: None for k in axes.DEFAULT_RULES}

        def mixed_body(params, data, pf_toks, pf_t0, pf_len, dec_toks,
                       dec_pos, dec_active, tables):
            with axes.axis_rules(no_rules):
                return transformer.lm_paged_mixed_step(
                    params, data, pf_toks, pf_t0, pf_len, dec_toks, dec_pos,
                    dec_active, tables, cfg_local, reduce_fn=psum_model)

        def decode_body(params, data, toks, pos, tables):
            with axes.axis_rules(no_rules):
                return transformer.lm_paged_decode_step(
                    params, data, toks, pos, tables, cfg_local,
                    reduce_fn=psum_model)

        pspecs = serve_param_specs(self.params, mesh)
        dspecs = jax.tree.map(lambda _: self.cache._data_spec,
                              self.cache.data)
        tspecs = {"page_table": P("data", None), "cmp_table": P("data", None),
                  "write_floor": P("data"), "cmp_write_floor": P("data")}
        row = P("data")
        self._mixed = jax.jit(
            shard_map_compat(
                mixed_body, mesh,
                in_specs=(pspecs, dspecs, P("data", None), row, row, row,
                          row, row, tspecs),
                out_specs=(P("data", None, None), P("data", None), dspecs)),
            donate_argnums=(1,))
        self._decode = jax.jit(
            shard_map_compat(
                decode_body, mesh,
                in_specs=(pspecs, dspecs, row, row, tspecs),
                out_specs=(P("data", None), dspecs)),
            donate_argnums=(1,))
        self._prefill = None     # sequential path unreachable (fused-only)
