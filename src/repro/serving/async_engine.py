"""Async request loop over the fused serving engine: per-request token
streaming with corrected latency stamps.

``AsyncEngine`` wraps an ``Engine`` in an asyncio service loop: callers
``await generate(...)`` (full output) or iterate ``stream(...)`` (tokens as
they materialize), from any number of concurrent coroutines.  One background
task drives ``engine.step()`` — each step is a fused mixed tick, so a newly
submitted prompt's chunked prefill overlaps with every in-flight request's
decode — and the engine's ``on_token`` / ``on_finish`` hooks fan tokens out
to per-request asyncio queues.

``engine.step()`` runs in the default executor (a thread), keeping the event
loop responsive while jax blocks; hook callbacks fire on that worker thread
and hop back to the loop via ``call_soon_threadsafe``.  The loop task drains
on idle and restarts on the next submission, so an ``AsyncEngine`` can serve
bursts indefinitely.

Example::

    aeng = AsyncEngine(Engine(cfg, n_slots=4))
    async for tok in aeng.stream(prompt, max_new=32):
        ...                         # tokens arrive as the engine emits them
    req = await aeng.generate(prompt, max_new=32)   # or collect everything
"""
from __future__ import annotations

import asyncio
import collections

from repro.serving.engine import Engine
from repro.serving.scheduler import Request

_DONE = object()        # stream sentinel: request finished


class AsyncEngine:
    """Asyncio front-end: concurrent submissions, per-request streaming.

    Finished-request timelines (submit/admit/first_chunk/first_token/finish
    wall clocks) are retained in a bounded LRU dict — ``timeline(rid)`` — so
    a long-running service can report per-request latency without keeping
    the requests' token lists alive (the engine's scheduler separately
    bounds those via ``retain_outputs``).
    """

    def __init__(self, engine: Engine, *, retain_timelines: int = 4096):
        self.engine = engine
        engine.on_token = self._on_token       # worker-thread callbacks
        engine.on_finish = self._on_finish
        self._queues: dict[int, asyncio.Queue] = {}
        self._loop: asyncio.AbstractEventLoop | None = None
        self._task: asyncio.Task | None = None
        # rid -> timeline dict; bounded so indefinite serving stays O(cap)
        self._timelines: "collections.OrderedDict[int, dict]" = \
            collections.OrderedDict()
        self.retain_timelines = retain_timelines

    # ------------------------------------------------- engine-thread hooks
    def _post(self, rid: int, item) -> None:
        q = self._queues.get(rid)
        if q is not None and self._loop is not None:
            self._loop.call_soon_threadsafe(q.put_nowait, item)

    def _on_token(self, req: Request, tok: int) -> None:
        self._post(req.rid, tok)

    def _on_finish(self, req: Request) -> None:
        self._timelines[req.rid] = req.timeline()
        while len(self._timelines) > self.retain_timelines:
            self._timelines.popitem(last=False)
        self._post(req.rid, _DONE)

    # ---------------------------------------------------------- telemetry
    def timeline(self, rid: int) -> dict | None:
        """Per-request lifecycle stamps for a finished request (None if the
        rid is unknown or already evicted past ``retain_timelines``)."""
        return self._timelines.get(rid)

    def timelines(self) -> dict:
        """{rid: timeline} for every retained finished request."""
        return dict(self._timelines)

    # ------------------------------------------------------- service loop
    def _ensure_running(self) -> None:
        self._loop = asyncio.get_running_loop()
        if self._task is None or self._task.done():
            self._task = self._loop.create_task(self._drive())

    async def _drive(self) -> None:
        loop = asyncio.get_running_loop()
        try:
            while not self.engine.scheduler.idle():
                await loop.run_in_executor(None, self.engine.step)
        except Exception as e:              # engine died: fail all streams
            for rid in list(self._queues):
                self._post(rid, e)
            raise

    # ------------------------------------------------------------- intake
    async def stream(self, prompt, max_new: int = 16,
                     eos_id: int | None = None):
        """Submit one request; yield its tokens as they materialize."""
        req = self.engine.submit(prompt, max_new=max_new, eos_id=eos_id)
        q: asyncio.Queue = asyncio.Queue()
        self._queues[req.rid] = q
        self._ensure_running()
        try:
            while True:
                item = await q.get()
                if item is _DONE:
                    return
                if isinstance(item, Exception):
                    raise item
                yield item
        finally:
            self._queues.pop(req.rid, None)

    async def generate(self, prompt, max_new: int = 16,
                       eos_id: int | None = None) -> Request:
        """Submit one request and await its completion (full ``Request``,
        with per-request ``submit_t``/``first_token_t``/``finish_t``)."""
        req = self.engine.submit(prompt, max_new=max_new, eos_id=eos_id)
        q: asyncio.Queue = asyncio.Queue()
        self._queues[req.rid] = q
        self._ensure_running()
        try:
            while True:
                item = await q.get()
                if item is _DONE:
                    return req
                if isinstance(item, Exception):
                    raise item
        finally:
            self._queues.pop(req.rid, None)

    async def drain(self) -> None:
        """Wait until all in-flight and queued requests have finished."""
        if self._task is not None:
            await self._task
