"""Radix-tree prefix cache: copy-on-write sharing of KV pages across requests.

Production traffic is dominated by requests sharing a system prompt, and the
paged NSA layout (page == one selected block) makes physical sharing clean:
the kernels address KV only through per-slot page tables, so N requests with
a common token prefix can point their leading table entries at ONE physical
copy.  K/V rows are a pure function of (params, token, absolute position),
so two slots whose prompts agree on the first ``k`` pages would compute
byte-identical page contents — this module lets the second slot skip both
the pages and the prefill work.

Structure: a radix/trie over fully-materialized prompt blocks.  A node at
depth ``k`` covers the prompt prefix of ``(k+1) * page_size`` tokens and is
keyed by the raw bytes of block ``k``'s tokens (exact match — chaining
through the tree encodes the prefix, so no hash-collision risk).  Each node
records

- ``raw_page``       — the physical raw-KV page holding block ``k``,
- ``cmp_full_new``   — compressed-token pages COMPLETED at this depth
  (every row's compression window ends inside the node's prefix, so the
  page is immutable from here on and can be aliased outright),
- ``cmp_boundary``   — the donor's partially-filled trailing compressed
  page at this depth, if any.  Partial pages are never aliased: a matching
  request copies the rows into a private page (copy-on-write at the
  boundary block) because its own prefill will keep appending rows there.

All referenced pages carry one trie reference in the pools' refcounts, so
cached prefixes survive the donor slot's release; ``evict_lru`` drops
least-recently-matched leaves when admission would otherwise fail.

Write safety: a matching slot starts prefill AT the matched offset and
decode writes land at ``pos >= prompt_len``, so no shared page is ever a
scatter target; the page tables additionally carry per-slot write floors
(``repro.core.paging.scatter_rows(min_pos=...)``) routing any write below
the shared prefix to the dump page.
"""
from __future__ import annotations

import dataclasses
import itertools

__all__ = ["PrefixCache", "PrefixMatch"]


class _Node:
    __slots__ = ("key", "parent", "children", "depth", "raw_page",
                 "cmp_full_new", "cmp_boundary", "last_used")

    def __init__(self, key, parent, depth, raw_page, cmp_full_new,
                 cmp_boundary, last_used):
        self.key = key                      # block-token bytes
        self.parent = parent
        self.children: dict[bytes, _Node] = {}
        self.depth = depth                  # block index (0-based)
        self.raw_page = raw_page
        self.cmp_full_new = cmp_full_new    # list[int], completed cmp pages
        self.cmp_boundary = cmp_boundary    # int | None, partial cmp page
        self.last_used = last_used


@dataclasses.dataclass
class PrefixMatch:
    """A matched (block-aligned) prompt prefix, with references pinned.

    ``match()`` takes one reference on every returned page so eviction or a
    donor release between match and admission cannot free them.  Exactly one
    of ``PagedNSACache.alloc_slot(prefix=match)`` (which consumes it) or
    ``cancel()`` must follow.
    """
    tokens: int                       # matched tokens (multiple of page_size)
    raw_pages: list[int]              # aliased raw pages, one per block
    cmp_pages: list[int]              # aliased FULL compressed pages
    cmp_boundary: int | None          # donor page to copy-on-write, if any
    _owner: "PrefixCache | None" = None
    _live: bool = True

    @property
    def blocks(self) -> int:
        return len(self.raw_pages)

    def cancel(self) -> None:
        """Drop the pinned references (no admission happened)."""
        if self._live and self._owner is not None:
            self._live = False
            self._owner._release_match(self)

    def consume(self) -> None:
        """Mark references as transferred to the admitting slot's tables
        (plus the boundary ref to the CoW copy step)."""
        self._live = False


class PrefixCache:
    """Radix index from token-block prefixes to physical pages."""

    def __init__(self, cache):
        self.cache = cache                          # PagedNSACache
        self.page_size = cache.page_size
        self._clock = itertools.count()
        self.root = _Node(None, None, -1, None, [], None, next(self._clock))
        self.n_blocks = 0                           # == number of nodes

    # ---------------------------------------------------------- geometry
    def _ncmp(self, n_tokens: int) -> int:
        """EXACT count of compressed tokens whose window lies entirely inside
        the first ``n_tokens`` (0 below one window — unlike
        ``num_cmp_blocks`` which floors at 1 for shape purposes)."""
        nsa = self.cache.cfg.nsa
        l, st = nsa.cmp_block_size, nsa.cmp_stride
        return 0 if n_tokens < l else (n_tokens - l) // st + 1

    def _cmp_full(self, n_tokens: int) -> int:
        """Compressed pages completely filled by the first ``n_tokens``."""
        return self._ncmp(n_tokens) // self.page_size

    @property
    def blocks_cached(self) -> int:
        return self.n_blocks

    # ------------------------------------------------------------- match
    def _walk(self, prompt, max_blocks: int) -> list[_Node]:
        p = self.page_size
        chain, node = [], self.root
        for k in range(max_blocks):
            key = prompt[k * p:(k + 1) * p].tobytes()
            child = node.children.get(key)
            if child is None:
                break
            chain.append(child)
            node = child
        return chain

    def match(self, prompt) -> PrefixMatch | None:
        """Longest cached block-aligned prefix of ``prompt``, refs pinned.

        Capped at ``len(prompt) - 1`` tokens rounded down to whole blocks:
        at least one prompt token is always prefilled so the request's first
        output token has logits to come from.
        """
        max_blocks = (len(prompt) - 1) // self.page_size
        chain = self._walk(prompt, max_blocks)
        if not chain:
            return None
        stamp = next(self._clock)
        for n in chain:
            n.last_used = stamp
        raw = [n.raw_page for n in chain]
        cmp_full = [pg for n in chain for pg in n.cmp_full_new]
        boundary = chain[-1].cmp_boundary
        self.cache.pool.share(raw)
        self.cache.cmp_pool.share(cmp_full)
        if boundary is not None:
            self.cache.cmp_pool.share([boundary])
        return PrefixMatch(tokens=len(chain) * self.page_size,
                           raw_pages=raw, cmp_pages=cmp_full,
                           cmp_boundary=boundary, _owner=self)

    def _release_match(self, m: PrefixMatch) -> None:
        self.cache.pool.release(m.raw_pages)
        self.cache.cmp_pool.release(m.cmp_pages)
        if m.cmp_boundary is not None:
            self.cache.cmp_pool.release([m.cmp_boundary])

    # ------------------------------------------------------------ insert
    def insert(self, prompt, slot: int) -> int:
        """Register ``slot``'s fully-materialized prompt blocks (call after
        its prefill completed).  Existing nodes are left untouched (their
        pages hold identical content by construction); new nodes pin one
        trie reference on each page they name.  Returns #blocks added."""
        p = self.page_size
        raw_pages = self.cache.tables[slot].pages
        cmp_pages = self.cache.cmp_tables[slot].pages
        blocks = len(prompt) // p
        node, added, stamp = self.root, 0, next(self._clock)
        for k in range(blocks):
            key = prompt[k * p:(k + 1) * p].tobytes()
            child = node.children.get(key)
            if child is None:
                f_prev, f_here = self._cmp_full(k * p), self._cmp_full((k + 1) * p)
                cmp_new = [int(g) for g in cmp_pages[f_prev:f_here]]
                boundary = None
                if (self._ncmp((k + 1) * p) > f_here * p
                        and f_here < len(cmp_pages)):
                    boundary = int(cmp_pages[f_here])
                child = _Node(key, node, k, int(raw_pages[k]), cmp_new,
                              boundary, stamp)
                self.cache.pool.share([child.raw_page])
                self.cache.cmp_pool.share(cmp_new)
                if boundary is not None:
                    self.cache.cmp_pool.share([boundary])
                node.children[key] = child
                self.n_blocks += 1
                added += 1
            else:
                child.last_used = stamp
            node = child
        return added

    # ---------------------------------------------------------- eviction
    def _leaves(self) -> list[_Node]:
        out, stack = [], list(self.root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                out.append(n)
        return out

    def _evict_node(self, n: _Node) -> None:
        self.cache.pool.release([n.raw_page])
        self.cache.cmp_pool.release(n.cmp_full_new)
        if n.cmp_boundary is not None:
            self.cache.cmp_pool.release([n.cmp_boundary])
        del n.parent.children[n.key]
        self.n_blocks -= 1

    def evict_lru(self, n: int = 1) -> int:
        """Evict up to ``n`` least-recently-matched leaf blocks (an evicted
        leaf exposes its parent, so repeated calls peel whole chains).
        Pages still referenced by live slots merely lose the trie reference.
        Returns the number of blocks evicted."""
        evicted = 0
        while evicted < n:
            leaves = self._leaves()
            if not leaves:
                break
            self._evict_node(min(leaves, key=lambda x: x.last_used))
            evicted += 1
        return evicted

    def evict_for(self, raw_needed: int, cmp_needed: int) -> bool:
        """Drop LRU cached prefixes until the pools can cover the request
        (called when ``can_admit`` would otherwise fail).  True on success."""
        pool, cpool = self.cache.pool, self.cache.cmp_pool
        while not (pool.can_alloc(raw_needed) and cpool.can_alloc(cmp_needed)):
            if self.evict_lru(1) == 0:
                return False
        return True

    def clear(self) -> None:
        while self.evict_lru(self.n_blocks or 1) > 0:
            pass
        self.root.children.clear()
