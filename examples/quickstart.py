"""Quickstart: the FSA kernel in three acts.

1. run NSA selected attention through the FSA-TPU Pallas kernel and check it
   against the dense oracle;
2. run the full three-branch NSA attention module;
3. train a tiny NSA-attention LM for a handful of steps.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.attention import (KernelPolicy, list_backends, nsa_attention,
                             selected_attention)
from repro.core import (NSAConfig, apply_gates, compressed_and_selection,
                        init_nsa_params)
from repro.kernels import ref

# ---------------------------------------------------------------- 1. kernel
cfg = NSAConfig(block_size=16, num_selected=4, cmp_block_size=8, cmp_stride=4,
                window_size=32, q_block_size=32, min_seq_for_sparse=1,
                policy=KernelPolicy(backend="fsa"))
N, h, h_k, d = 256, 4, 2, 32
ks = jax.random.split(jax.random.PRNGKey(0), 5)
q = jax.random.normal(ks[0], (N, h, d))
k = jax.random.normal(ks[1], (N, h_k, d))
v = jax.random.normal(ks[2], (N, h_k, d))
params = init_nsa_params(ks[3], 64, h, d, cfg)

_, idx, valid = compressed_and_selection(params, q, k, v, cfg, q_chunk=64)
out_kernel = selected_attention(q, k, v, idx, valid, cfg)   # policy: fsa
out_oracle = ref.selected_ref(q, k, v, idx, valid, cfg)
err = float(jnp.abs(out_kernel - out_oracle).max())
print(f"[1] FSA selected-attention kernel vs oracle: max err {err:.2e}")

# ---------------------------------------------------------------- 2. module
# one entry for every backend in the registry; "auto" resolves by capability
print(f"[2] registered attention backends: {', '.join(list_backends())}")
gates = apply_gates(params, jax.random.normal(ks[4], (N, 64)))
out = nsa_attention(params, gates, q, k, v, cfg=cfg, mode="prefill",
                    backend="fsa")
print(f"    full NSA module via backend='fsa': {out.shape}, "
      f"finite={bool(jnp.isfinite(out).all())}")

# ---------------------------------------------------------------- 3. train
from repro.configs import get_config, reduced
from repro.launch.mesh import make_mesh
from repro.launch.train import train_loop
from repro.runtime.fault_tolerance import FTConfig

cfg_lm = reduced(get_config("codeqwen1.5-7b"))
mesh = make_mesh((1, 1), ("data", "model"))
_, losses = train_loop(cfg_lm, steps=10, batch=4, seq=128, mesh=mesh,
                       ft=FTConfig(ckpt_dir="/tmp/quickstart_ckpt",
                                   ckpt_every=0,
                                   heartbeat_path="/tmp/quickstart_hb.json"),
                       quiet=True)
print(f"[3] 10 training steps on a tiny NSA LM: loss {losses[0]:.3f} -> "
      f"{losses[-1]:.3f}")
