"""End-to-end driver: train a ~100M-parameter NSA LM for a few hundred steps.

The model is a 12L/768d/12H dense transformer with NSA attention (~110M
params incl. embeddings) on the deterministic synthetic stream.  Checkpoints,
heartbeat, straggler monitoring and auto-resume are all live — kill the
process and rerun to continue from the newest checkpoint.

Full run:   PYTHONPATH=src python examples/train_lm.py --steps 300
Smoke run:  PYTHONPATH=src python examples/train_lm.py --steps 5 --small
Compare:    PYTHONPATH=src python examples/train_lm.py --compare --steps 40
            (NSA vs full attention loss curves — paper Fig. 10 analogue)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib

from repro.configs.base import ModelConfig
from repro.core.nsa_config import NSAConfig
from repro.launch.mesh import make_mesh
from repro.launch.train import train_loop
from repro.optim import AdamWConfig
from repro.runtime.fault_tolerance import FTConfig

CFG_100M = ModelConfig(
    name="nsa-110m", family="lm",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,   # g = 3 (FSA regime)
    d_ff=2048, vocab=32000, mlp="swiglu", attention="nsa",
    nsa=NSAConfig(block_size=32, num_selected=8, cmp_block_size=16,
                  cmp_stride=8, window_size=128, q_block_size=64),
    q_chunk=256, dtype="float32", scan_layers=True,
)

CFG_SMALL = dataclasses.replace(
    CFG_100M, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab=2048,
    nsa=NSAConfig(block_size=16, num_selected=4, cmp_block_size=8,
                  cmp_stride=4, window_size=32, q_block_size=32,
                  min_seq_for_sparse=1))


def run(cfg, steps, seq, batch, outdir, tag):
    mesh = make_mesh((1, 1), ("data", "model"))
    ft = FTConfig(ckpt_dir=str(outdir / f"ckpt_{tag}"), ckpt_every=100,
                  heartbeat_path=str(outdir / f"hb_{tag}.json"))
    _, losses = train_loop(cfg, steps=steps, batch=batch, seq=seq, mesh=mesh,
                           ft=ft, opt_cfg=AdamWConfig(lr=3e-4),
                           log_every=10)
    (outdir / f"losses_{tag}.json").write_text(json.dumps(losses))
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--compare", action="store_true",
                    help="train NSA vs full attention (Fig. 10 analogue)")
    ap.add_argument("--out", default="experiments/train_lm")
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    cfg = CFG_SMALL if args.small else CFG_100M

    if args.compare:
        curves = {}
        for attn in ("nsa", "full"):
            c = dataclasses.replace(cfg, attention=attn)
            curves[attn] = run(c, args.steps, args.seq, args.batch, outdir,
                               f"cmp_{attn}")
        print("\nstep  nsa_loss  full_loss")
        for i in range(0, args.steps, max(1, args.steps // 20)):
            print(f"{i:4d}  {curves['nsa'][i]:.4f}    {curves['full'][i]:.4f}")
        (outdir / "compare.json").write_text(json.dumps(curves))
        return

    losses = run(cfg, args.steps, args.seq, args.batch, outdir, "main")
    n = len(losses)
    print(f"\n[train_lm] {n} steps: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(mean last 10: {sum(losses[-10:]) / min(10, n):.4f})")


if __name__ == "__main__":
    main()
