"""Batched serving example: prefill a batch of prompts, decode with NSA.

The decode path touches only compressed tokens + top-T selected blocks + the
local window per step — O(N/stride) per token instead of O(N).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch h2o-danube-3-4b
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, reduced
from repro.launch.serve import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    eng = Engine(cfg, batch_slots=args.batch,
                 max_len=args.prompt_len + args.new_tokens + 8)
    reqs = [Request(i,
                    jax.random.randint(jax.random.PRNGKey(i),
                                       (args.prompt_len,), 0, cfg.vocab),
                    max_new=args.new_tokens)
            for i in range(args.batch)]
    stats = eng.run(reqs, args.new_tokens)
    print(f"[serve_lm] arch={args.arch} (reduced) batch={args.batch} "
          f"prompt={args.prompt_len}")
    print(f"  prefill: {stats['prefill_s']*1e3:.1f} ms")
    print(f"  decode:  {stats['decode_s_per_token']*1e3:.1f} ms/token "
          f"(batched over {args.batch} slots)")
    for r in reqs[:2]:
        print(f"  request {r.rid}: {r.out}")


if __name__ == "__main__":
    main()
