"""Mixed-length serving example: continuous batching on the paged NSA cache.

Submits more variable-length prompts than there are decode slots; the engine
admits them as slots and pages free up, prefills in fixed-size chunks, and
decodes every active slot at its own absolute position.  The NSA decode path
touches only compressed tokens + top-T selected pages + the local window per
step — O(N/stride) per token instead of O(N).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch h2o-danube-3-4b
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config, reduced
from repro.serving import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--max-prompt", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    eng = Engine(cfg, n_slots=args.slots,
                 max_len=args.max_prompt + args.new_tokens + 8)
    rng = np.random.default_rng(0)
    reqs = []
    for _ in range(args.requests):
        plen = int(rng.integers(max(args.max_prompt // 4, 1),
                                args.max_prompt + 1))
        reqs.append(eng.submit(rng.integers(0, cfg.vocab, size=(plen,)),
                               max_new=args.new_tokens))

    print(f"[serve_lm] arch={args.arch} (reduced) slots={args.slots} "
          f"requests={args.requests} prompt lens="
          f"{[len(r.prompt) for r in reqs]}")
    while not eng.scheduler.idle():
        ev = eng.step()
        if ev["admitted"] or ev["finished"]:
            print(f"  admitted={[r.rid for r in ev['admitted']]} "
                  f"finished={[r.rid for r in ev['finished']]} "
                  f"active={ev['active']} queued={ev['pending']} "
                  f"pages={ev['page_util']['raw']:.0%}")
    s = eng.summary()
    print(f"  decode: {s['decode_tokens_per_s']:.1f} tok/s "
          f"({s['decode_ms_per_tick']:.1f} ms/tick batched)  "
          f"prefill: {s['prefill_tokens_per_s']:.1f} tok/s  "
          f"peak pages: {s['peak_page_util']:.0%}")
    for r in reqs[:3]:
        print(f"  request {r.rid} (prompt {len(r.prompt)}): {r.out}")


if __name__ == "__main__":
    main()
