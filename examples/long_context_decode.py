"""Long-context decode: NSA's sub-quadratic serving path.

Builds a context of ``--context`` tokens, then decodes with (a) the NSA path
(compressed + selected + sliding reads = O(N/stride) per token) and (b) full
attention over the whole cache (O(N) per token), timing both.

Run:  PYTHONPATH=src python examples/long_context_decode.py --context 4096
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import build


def time_decode(cfg, context: int, steps: int = 8):
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(1, context + steps + 1)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, context), 0,
                                cfg.vocab)
    batch = {"tokens": prompt, "labels": jnp.full_like(prompt, -100)}
    logits, cache = jax.jit(model.prefill)(params, cache, batch)
    tok = jnp.argmax(logits[:, :cfg.vocab], -1).astype(jnp.int32)

    step = jax.jit(model.decode_step)
    logits, cache = step(params, cache, tok, jnp.asarray(context))  # warm
    t0 = time.perf_counter()
    for i in range(1, steps):
        logits, cache = step(params, cache, tok, jnp.asarray(context + i))
        jax.block_until_ready(logits)
    return (time.perf_counter() - t0) / (steps - 1) * 1e3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--context", type=int, default=2048)
    args = ap.parse_args()

    base = reduced(get_config(args.arch))
    nsa_cfg = dataclasses.replace(base, attention="nsa")
    full_cfg = dataclasses.replace(base, attention="full")

    ms_nsa = time_decode(nsa_cfg, args.context)
    ms_full = time_decode(full_cfg, args.context)
    n_cmp = nsa_cfg.nsa.num_cmp_blocks(args.context)
    touched = (n_cmp + nsa_cfg.nsa.num_selected * nsa_cfg.nsa.block_size
               + nsa_cfg.nsa.window_size)
    print(f"[long_context_decode] context={args.context} (reduced "
          f"{args.arch})")
    print(f"  NSA decode:  {ms_nsa:.1f} ms/token  "
          f"(touches ~{touched} of {args.context} cached tokens)")
    print(f"  full decode: {ms_full:.1f} ms/token  (touches all "
          f"{args.context})")
    print(f"  KV read reduction: {args.context / touched:.1f}x")


if __name__ == "__main__":
    main()
