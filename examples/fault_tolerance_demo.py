"""Fault-tolerance demo: kill a training job mid-run, resume bit-identically.

Phase 1 trains 12 steps checkpointing every 4, then "crashes".
Phase 2 restarts and must (a) resume from step 12's checkpoint and (b)
reproduce the exact losses a never-crashed run would have produced — the
deterministic step-indexed data pipeline makes restart bit-identical.

Run:  PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
from __future__ import annotations

import pathlib
import shutil

import numpy as np

from repro.configs import get_config, reduced
from repro.launch.mesh import make_mesh
from repro.launch.train import train_loop
from repro.runtime.fault_tolerance import FTConfig

OUT = pathlib.Path("/tmp/ft_demo")


def main():
    shutil.rmtree(OUT, ignore_errors=True)
    OUT.mkdir(parents=True)
    cfg = reduced(get_config("codeqwen1.5-7b"))
    mesh = make_mesh((1, 1), ("data", "model"))

    ft = FTConfig(ckpt_dir=str(OUT / "ckpt"), ckpt_every=4,
                  heartbeat_path=str(OUT / "hb.json"))

    # --- reference: uninterrupted 20-step run ---
    ft_ref = FTConfig(ckpt_dir=str(OUT / "ckpt_ref"), ckpt_every=0,
                      heartbeat_path=str(OUT / "hb_ref.json"))
    _, ref_losses = train_loop(cfg, steps=20, batch=4, seq=128, mesh=mesh,
                               ft=ft_ref, quiet=True)

    # --- phase 1: run 12 steps, checkpoint at 4/8/12, then "crash" ---
    _, l1 = train_loop(cfg, steps=12, batch=4, seq=128, mesh=mesh, ft=ft,
                       quiet=True)
    print(f"[ft_demo] phase 1: ran steps 0..11, crashed after step 11 "
          f"(checkpoints at 4, 8, 12)")

    # --- phase 2: restart; auto-resumes from step 12's checkpoint ---
    _, l2 = train_loop(cfg, steps=20, batch=4, seq=128, mesh=mesh, ft=ft,
                       quiet=True)
    print(f"[ft_demo] phase 2: resumed, ran steps 12..19")

    resumed = l1 + l2
    np.testing.assert_allclose(resumed, ref_losses, rtol=1e-5)
    print("[ft_demo] PASS: crash+resume losses are bit-identical to the "
          "uninterrupted run")
    print("          steps 10..14:",
          [round(x, 4) for x in ref_losses[10:15]], "(reference)")
    print("                       ",
          [round(x, 4) for x in resumed[10:15]], "(crashed+resumed)")


if __name__ == "__main__":
    main()
